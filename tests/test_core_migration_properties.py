"""Bytes-plane migration round-trip properties (hypothesis, shimmed).

Mirrors ``tests/test_bucket_properties.py`` one layer up: where that file
pins ``TokenBucket.snapshot/restore``, this one pins the whole
``CoreEngine.export_tenant`` -> ``import_tenant`` transfer and the
``ConservationLedger`` invariant the cluster asserts on every plan:

  * an export/import round trip preserves the tenant's bucket level,
    rate and capacity exactly (a migration can never mint a fresh burst
    of bytes, nor lose burned-down level);
  * carried + live counters are invariant under ARBITRARY sequences of
    traffic and export/fold/import moves across a fleet of engines —
    byte continuity is a property of the protocol, not of one lucky
    interleaving;
  * conservation (carried + live == summed billed ground truth) holds at
    every step of every such sequence.

Runs under real hypothesis when installed, the deterministic fallback of
``tests/_hyp.py`` otherwise.
"""
import pytest

from repro.core.engine import CoreEngine
from repro.core.nqe import CommOp
from repro.fabric import ConservationLedger

from _hyp import given, settings, st

_RATES = st.floats(min_value=0.1, max_value=1e4)
_CAPS = st.floats(min_value=1.0, max_value=1e5)
_TIMES = st.floats(min_value=0.0, max_value=100.0)
_SIZES = st.integers(min_value=1, max_value=1 << 16)
_OPS = st.lists(_SIZES, min_size=0, max_size=6)
# one fleet event: (engine the tenant currently routes through is implied;
# value picks the NEXT destination engine and the op burst between moves)
_MOVES = st.lists(st.tuples(st.integers(min_value=0, max_value=2),
                            _SIZES),
                  min_size=1, max_size=8)


def _pump(engine, tenant, sizes, now):
    for sz in sizes:
        op = CommOp(verb="psum", axes=("pod",), tenant_id=tenant,
                    size_bytes=int(sz))
        engine.admit(op, now)
        engine.route(op)


@settings(max_examples=40)
@given(rate=_RATES, cap=_CAPS, ops=_OPS, t0=_TIMES)
def test_export_import_preserves_bucket_level_and_rate(rate, cap, ops, t0):
    """The enforcement state survives a migration bit-for-bit: rate,
    capacity, and the burned-down token level all travel."""
    src = CoreEngine(enforcement="account")
    dst = CoreEngine(enforcement="account")
    src.set_tenant_rate(1, rate, burst=cap)
    _pump(src, 1, ops, t0)
    level = src.buckets[1].snapshot(now=t0)["tokens"]
    state = src.export_tenant(1, now=t0)
    dst.import_tenant(1, state, now=t0)
    b = dst.buckets[1]
    assert b.rate == rate
    assert b.capacity == cap
    assert b.tokens == pytest.approx(level, rel=1e-9, abs=1e-9)
    assert 0.0 <= b.tokens <= b.capacity + 1e-9
    # and the source is fully quiesced (re-import back is legal)
    assert not src.has_tenant(1)
    src.import_tenant(1, dst.export_tenant(1, now=t0), now=t0)
    assert src.buckets[1].tokens == pytest.approx(b.tokens)


@settings(max_examples=40)
@given(rate=_RATES, cap=_CAPS, moves=_MOVES, t0=_TIMES)
def test_carried_plus_live_invariant_under_arbitrary_sequences(rate, cap,
                                                               moves, t0):
    """Byte continuity as a property: however traffic and migrations
    interleave across a 3-engine fleet, carried + live counters equal the
    total bytes ever routed, and conservation holds at every step."""
    fleet = [CoreEngine(enforcement="account") for _ in range(3)]
    led = ConservationLedger(fleet)
    cur, pumped, now = 0, 0, t0
    fleet[cur].set_tenant_rate(1, rate, burst=cap)
    for dst, nbytes in moves:
        _pump(fleet[cur], 1, [nbytes], now)
        pumped += int(nbytes)
        assert led.total(1) == pumped
        led.assert_conservation(1)
        if dst != cur:
            state = fleet[cur].export_tenant(1, now=now)
            led.fold(1, fleet[cur], state)
            # mid-move: the live side forgot, the carried side remembers
            assert led.total(1) == pumped
            fleet[dst].import_tenant(1, state, now=now)
            cur = dst
            assert led.total(1) == pumped
            led.assert_conservation(1)
        now += 0.25
    # ops are conserved too, not just bytes
    assert led.merged("ops").get(1, 0) == len(moves)
    assert led.ground_truth(1) == pumped


@settings(max_examples=40)
@given(rate=_RATES, cap=_CAPS, ops=_OPS, t0=_TIMES)
def test_exported_counters_never_replay_into_the_destination(rate, cap,
                                                             ops, t0):
    """The carried counters are the operator's to fold — importing must
    not replay them (a counter jump would read as a rate spike to
    telemetry), so the destination's live ledger starts at zero."""
    src = CoreEngine(enforcement="account")
    dst = CoreEngine(enforcement="account")
    src.set_tenant_rate(1, rate, burst=cap)
    _pump(src, 1, ops, t0)
    total = src.total_bytes(1)
    state = src.export_tenant(1, now=t0)
    assert state.carried["bytes"] == total
    dst.import_tenant(1, state, now=t0)
    assert dst.total_bytes(1) == 0
    assert dst.billed_ground_truth(1) == 0
    # the ground truth stayed on the source — migration-invariant
    assert src.billed_ground_truth(1) == total
