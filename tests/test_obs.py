"""The fabric flight recorder: metrics registry, tracer, latency hists.

Four claims under test:

  * the Prometheus text export is spec-compliant — HELP/TYPE per family,
    label escaping, ``+Inf``/``NaN`` rendering, cumulative histogram
    buckets — and round-trips through the strict scrape-side parser;
  * the two telemetry planes export ``telemetry_updates_total`` as two
    *distinct* labeled series (the name-collision regression), and the
    registry refuses genuine duplicates naming both sources;
  * histogram quantile estimates bracket the true sample quantile within
    one bucket (property-tested via the tests/_hyp shim);
  * the tracer records the full stack-module lifecycle as Chrome
    trace-event JSON — stable names/phases for the migration scenario,
    valid JSON, monotonic timestamps per track (the golden-trace test,
    validated by tools/check_trace.py itself).
"""
import importlib.util
import json
import math
import pathlib

import pytest

from _hyp import given, settings, st
from test_placement import make_fake_cluster

from repro.obs import (
    Histogram, MetricsRegistry, NullTracer, TenantHistograms, Tracer,
    escape_label_value, format_value, parse_prometheus_text,
    parse_series_key, render_prometheus, trace_to,
)
from repro.obs import tracing
from repro.serve.scheduler import Request

_CHECK_TRACE = pathlib.Path(__file__).resolve().parents[1] \
    / "tools" / "check_trace.py"
_spec = importlib.util.spec_from_file_location("check_trace", _CHECK_TRACE)
check_trace_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace_mod)


# ---------------------------------------------------------------------------
# Prometheus text format
# ---------------------------------------------------------------------------


def test_render_emits_help_and_type_once_per_family():
    text = render_prometheus({
        "nk_cluster_engines": 3.0,
        'nk_engine_load{engine="0"}': 0.5,
        'nk_engine_load{engine="1"}': 0.25,
    })
    assert text.count("# HELP nk_engine_load") == 1
    assert text.count("# TYPE nk_engine_load gauge") == 1
    assert text.count("# TYPE nk_cluster_engines gauge") == 1
    # every non-comment line is a sample
    samples = [ln for ln in text.splitlines() if not ln.startswith("#")]
    assert len(samples) == 3


def test_metric_types_inferred_from_name():
    text = render_prometheus({
        "nk_cluster_steps_total": 7.0,
        'nk_admit_wait_seconds_bucket{le="+Inf",tenant="0"}': 2.0,
        'nk_admit_wait_seconds_sum{tenant="0"}': 0.5,
        'nk_admit_wait_seconds_count{tenant="0"}': 2.0,
    })
    assert "# TYPE nk_cluster_steps_total counter" in text
    assert "# TYPE nk_admit_wait_seconds histogram" in text
    # the histogram family gets ONE header covering bucket/sum/count
    assert text.count("# TYPE nk_admit_wait_seconds") == 1


def test_label_escaping_round_trips():
    nasty = 'quote " backslash \\ newline \n done'
    esc = escape_label_value(nasty)
    assert "\n" not in esc
    key = f'nk_migration_info{{tenant="{esc}"}}'
    name, labels = parse_series_key(key)
    assert name == "nk_migration_info"
    assert dict(labels)["tenant"] == nasty
    text = render_prometheus({key: 1.0})
    parsed = parse_prometheus_text(text)
    assert parsed[(name, labels)] == 1.0


def test_special_values_render_and_parse():
    assert format_value(float("inf")) == "+Inf"
    assert format_value(float("-inf")) == "-Inf"
    assert format_value(float("nan")) == "NaN"
    text = render_prometheus({"nk_engine_load": float("inf"),
                              "nk_cluster_parked": float("nan")})
    parsed = parse_prometheus_text(text)
    assert parsed[("nk_engine_load", ())] == float("inf")
    assert math.isnan(parsed[("nk_cluster_parked", ())])


def test_parser_rejects_duplicate_series_and_garbage():
    with pytest.raises(ValueError):
        parse_prometheus_text("nk_x 1\nnk_x 2\n")
    with pytest.raises(ValueError):
        parse_prometheus_text("not a metric line at all!\n")
    with pytest.raises(ValueError):
        parse_prometheus_text("# TYPE nk_x flub\nnk_x 1\n")


def test_render_parse_round_trip_preserves_every_series():
    counters = {
        "nk_cluster_engines": 3.0,
        'telemetry_updates_total{plane="serve"}': 12.0,
        'telemetry_updates_total{plane="bytes"}': 9.0,
        'nk_engine_load{engine="2"}': 0.125,
        'nk_migration_info{dst="1",seq="8",src="0",tenant="0"}': 8.0,
    }
    parsed = parse_prometheus_text(render_prometheus(counters))
    assert len(parsed) == len(counters)
    for key, value in counters.items():
        assert parsed[parse_series_key(key)] == value


# ---------------------------------------------------------------------------
# the telemetry name-collision regression + registry
# ---------------------------------------------------------------------------


def _both_planes():
    import numpy as np

    from repro.control.telemetry import EngineTelemetry, SchedulerTelemetry
    from repro.core.engine import CoreEngine
    from repro.serve.scheduler import TenantScheduler

    class _Payload:
        dtype = np.uint8

        def __init__(self, n):
            self.shape = (int(n),)

    sched = TenantScheduler()
    sched.add_tenant(0, rate_tokens_per_s=8.0)
    stel = SchedulerTelemetry(sched)
    stel.update(0.0)
    stel.update(1.0)
    core = CoreEngine(enforcement="account")
    core.set_tenant_rate(0, 1e6)
    core.dispatch("shm_move", _Payload(256), ("pod",), tenant_id=0, now=0.5)
    etel = EngineTelemetry(core)
    etel.update(0.0)
    etel.update(1.0)
    return stel, etel


def test_telemetry_updates_are_distinct_labeled_series():
    """Regression: both planes used to export bare
    ``telemetry_updates_total``; one silently shadowed the other in any
    combined scrape. Now each carries its plane label."""
    stel, etel = _both_planes()
    reg = MetricsRegistry()
    reg.register_provider(stel, name="serve-telemetry")
    reg.register_provider(etel, name="bytes-telemetry")
    parsed = parse_prometheus_text(reg.export_prometheus())
    planes = {dict(lbl)["plane"]: v for (n, lbl), v in parsed.items()
              if n == "telemetry_updates_total"}
    assert set(planes) == {"serve", "bytes"}
    assert planes["serve"] == stel.updates
    assert planes["bytes"] == etel.updates


def test_registry_rejects_duplicate_series_naming_both_sources():
    _, etel = _both_planes()
    _, etel2 = _both_planes()
    reg = MetricsRegistry()
    reg.register_provider(etel, name="first")
    reg.register_provider(etel2, name="second")
    with pytest.raises(ValueError) as ei:
        reg.collect()
    assert "first" in str(ei.value) and "second" in str(ei.value)


def test_registry_instruments_and_providers_export_together():
    reg = MetricsRegistry()
    c = reg.counter("nk_test_events_total", "Test events")
    g = reg.gauge("nk_test_depth", "Test depth")
    h = reg.histogram("nk_test_wait_seconds", "Test waits")
    c.inc()
    c.inc(2.0, tenant="0")
    g.set(4.0)
    h.observe(0.01, tenant="0")
    reg.register_provider(lambda: {"nk_provider_value": 1.0},
                          name="fn-provider")
    parsed = parse_prometheus_text(reg.export_prometheus())
    assert parsed[("nk_test_events_total", ())] == 1.0
    assert parsed[("nk_test_events_total", (("tenant", "0"),))] == 2.0
    assert parsed[("nk_test_depth", ())] == 4.0
    assert parsed[("nk_provider_value", ())] == 1.0
    assert parsed[("nk_test_wait_seconds_count", (("tenant", "0"),))] == 1.0


# ---------------------------------------------------------------------------
# latency histograms
# ---------------------------------------------------------------------------


def test_histogram_basic_stats_and_quantiles():
    h = Histogram()
    for v in (0.001, 0.01, 0.01, 0.1, 1.0):
        h.observe(v)
    assert h.total == 5
    assert h.min == pytest.approx(0.001)
    assert h.max == pytest.approx(1.0)
    assert h.mean == pytest.approx(sum((0.001, 0.01, 0.01, 0.1, 1.0)) / 5)
    # the p50 estimate is the upper edge of the bucket holding the median
    lo, hi = h.quantile_bounds(0.50)
    assert lo <= 0.01 <= hi
    assert h.quantile(0.50) == hi


def test_histogram_merge_since_and_payload_round_trip():
    a, b = Histogram(), Histogram()
    for v in (0.002, 0.02):
        a.observe(v)
    b.observe(0.2)
    snap = a.copy()
    a.observe(0.5)
    win = a.since(snap)
    assert win.total == 1
    assert win.quantile(0.99) >= 0.5       # the new sample's bucket edge
    a.merge(b)
    assert a.total == 4
    back = Histogram.from_payload(a.to_payload())
    assert back.total == a.total
    assert back.counts == a.counts
    assert back.sum == pytest.approx(a.sum)


def test_histogram_counters_are_cumulative_and_parse():
    h = Histogram()
    for v in (0.001, 0.05, 5.0, 1e9):       # 1e9 lands in overflow
        h.observe(v)
    c = h.counters("nk_admit_wait_seconds", tenant="7")
    text = render_prometheus(c)
    parsed = parse_prometheus_text(text)
    inf_key = parse_series_key(
        'nk_admit_wait_seconds_bucket{tenant="7",le="+Inf"}')
    assert parsed[inf_key] == 4.0
    assert parsed[("nk_admit_wait_seconds_count", (("tenant", "7"),))] == 4.0
    # cumulative: counts never decrease as le rises
    buckets = sorted(
        ((float("inf") if dict(lbl)["le"] == "+Inf"
          else float(dict(lbl)["le"])), v)
        for (n, lbl), v in parsed.items() if n.endswith("_bucket"))
    values = [v for _, v in buckets]
    assert values == sorted(values)


@settings(max_examples=60, deadline=None)
@given(samples=st.lists(st.floats(min_value=1e-4, max_value=500.0),
                        min_size=1, max_size=200),
       q=st.sampled_from([0.5, 0.9, 0.95, 0.99]))
def test_quantile_bounds_bracket_true_sample_quantile(samples, q):
    """The histogram estimate stays within one bucket of the truth."""
    h = Histogram()
    for v in samples:
        h.observe(v)
    rank = max(1, math.ceil(q * len(samples)))
    truth = sorted(samples)[rank - 1]
    lo, hi = h.quantile_bounds(q)
    assert lo <= truth <= hi or truth == pytest.approx(lo) \
        or truth == pytest.approx(hi)
    assert h.quantile(q) == hi


@settings(max_examples=40, deadline=None)
@given(samples=st.lists(st.floats(min_value=1e-3, max_value=50.0),
                        min_size=2, max_size=80),
       split=st.integers(min_value=1, max_value=79))
def test_histogram_merge_equals_observing_everything(samples, split):
    split = min(split, len(samples) - 1)
    a, b, whole = Histogram(), Histogram(), Histogram()
    for v in samples[:split]:
        a.observe(v)
    for v in samples[split:]:
        b.observe(v)
    for v in samples:
        whole.observe(v)
    a.merge(b)
    assert a.counts == whole.counts
    assert a.total == whole.total
    assert a.sum == pytest.approx(whole.sum)


def test_tenant_histograms_track_pop_and_merge():
    th = TenantHistograms("nk_ttft_seconds")
    th.observe(0, 0.01)
    th.observe(1, 0.1)
    th.observe(0, 0.02)
    assert th.get(0).total == 2
    c = th.counters()
    assert any("tenant=\"1\"" in k for k in c)
    popped = th.pop(0)
    assert popped.total == 2
    assert th.get(0).total == 0            # gone; get() hands back empty
    th.absorb(0, popped)
    assert th.get(0).total == 2


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_null_tracer_is_default_and_inert():
    assert isinstance(tracing.TRACER, NullTracer)
    assert not tracing.TRACER.enabled
    # every recording call is a no-op returning None
    assert tracing.TRACER.instant("t", "x", 0.0) is None
    assert tracing.TRACER.span("t", "x", 0.0, 1.0) is None
    assert tracing.TRACER.async_begin("t", "x", 1, 0.0) is None
    assert tracing.TRACER.async_end("t", "x", 1, 1.0) is None


def test_trace_to_swaps_and_restores_the_global():
    before = tracing.TRACER
    with trace_to() as tr:
        assert tracing.TRACER is tr and tr.enabled
        tr.instant("track", "evt", 1.5, tenant=3)
    assert tracing.TRACER is before


def test_tracer_event_encoding():
    tr = Tracer()
    tr.span("cluster", "migrate.transfer", 1.0, 1.0, tenant=0)
    tr.instant("cluster", "park", 2.0, engine=1)
    tr.async_begin("cluster", "migrate.drain", 0, 1.0)
    tr.async_end("cluster", "migrate.drain", 0, 1.25)
    doc = tr.chrome_trace()
    by_ph = {}
    for ev in doc["traceEvents"]:
        by_ph.setdefault(ev["ph"], []).append(ev)
    assert set(by_ph) == {"M", "X", "i", "b", "e"}
    x = by_ph["X"][0]
    assert x["ts"] == 1_000_000 and x["dur"] == 0
    assert isinstance(x["ts"], int)
    assert x["args"]["tenant"] == 0
    assert by_ph["i"][0]["s"] == "t"
    assert by_ph["b"][0]["id"] == by_ph["e"][0]["id"]
    assert json.loads(tr.to_json())["traceEvents"]
    assert tr.counters()["nk_trace_events_total"] == 4.0


# ---------------------------------------------------------------------------
# the golden migration trace (jit-free fake cluster)
# ---------------------------------------------------------------------------

# the stable lifecycle signature: every (name, ph) the scenario below
# must emit on the cluster track, in order
GOLDEN_LIFECYCLE = [
    ("migrate.transfer", "X"), ("migrate.drain", "b"),
    ("migrate.drain", "e"), ("migrate.finalize", "X"),
    ("migrate.transfer", "X"), ("migrate.drain", "b"),
    ("migrate.drain", "e"), ("migrate.finalize", "X"),
    ("park", "i"), ("unpark", "i"),
]

LIFECYCLE_NAMES = {"migrate.transfer", "migrate.drain", "migrate.finalize",
                   "park", "unpark"}


def _traced_fake_migration():
    with trace_to() as tr:
        cl = make_fake_cluster(3)
        for t in range(3):
            cl.add_tenant(t, engine=t)
            cl.submit(Request(t, [1, 2], 4, req_id=t, arrival=0.0))
        for i in range(8):
            cl.step(now=0.1 * (i + 1))
        cl.migrate(0, 1, now=1.0)            # operator rebalance
        for i in range(4):
            cl.step(now=1.0 + 0.1 * (i + 1))
        cl.migrate(2, 0, now=2.0)            # drain engine 2...
        for i in range(4):
            cl.step(now=2.0 + 0.1 * (i + 1))
        cl.park(2, now=3.0)                  # ...maintenance window
        cl.unpark(2, now=3.5)
    return tr


def test_golden_migration_trace_names_and_phases_are_stable():
    tr = _traced_fake_migration()
    doc = json.loads(tr.to_json())             # valid JSON by construction
    lifecycle = [(e["name"], e["ph"]) for e in doc["traceEvents"]
                 if e.get("name") in LIFECYCLE_NAMES]
    assert lifecycle == GOLDEN_LIFECYCLE
    # the scheduler's request lifecycle shows up too (FakeEngine admits
    # through the real TenantScheduler; dispatch/finish are ServeEngine's)
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"request.arrival", "request.admit"} <= names


def test_golden_migration_trace_passes_the_validator():
    tr = _traced_fake_migration()
    doc = json.loads(tr.to_json())
    assert check_trace_mod.check_trace(doc, scenario="migration") == []


def test_trace_timestamps_monotonic_per_track():
    tr = _traced_fake_migration()
    last = {}
    for ev in tr.chrome_trace()["traceEvents"]:
        if ev["ph"] in ("M", "b", "e"):
            continue
        track = (ev["pid"], ev["tid"])
        assert ev["ts"] >= last.get(track, -1)
        last[track] = max(last.get(track, -1),
                          ev["ts"] + ev.get("dur", 0))


def test_disabled_tracer_records_nothing_during_cluster_run():
    set_before = tracing.TRACER
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    cl.submit(Request(0, [1, 2], 4, req_id=0, arrival=0.0))
    for i in range(4):
        cl.step(now=0.1 * (i + 1))
    cl.migrate(0, 1, now=1.0)
    assert tracing.TRACER is set_before      # nothing swapped it
    assert not tracing.TRACER.enabled


def test_cluster_counters_include_latency_histograms_and_moves():
    cl = make_fake_cluster(3)
    for t in range(3):
        cl.add_tenant(t)
        cl.submit(Request(t, [1, 2], 4, req_id=10 + t, arrival=0.0))
    for i in range(6):
        cl.step(now=0.1 * (i + 1))
    cl.migrate(0, (cl.placement[0] + 1) % 3, now=1.0)
    for i in range(4):
        cl.step(now=1.0 + 0.1 * (i + 1))
    parsed = parse_prometheus_text(
        render_prometheus(cl.counters()))
    names = {n for n, _ in parsed}
    assert "nk_admit_wait_seconds_bucket" in names
    assert "nk_migration_info" in names
    info = [(dict(lbl), v) for (n, lbl), v in parsed.items()
            if n == "nk_migration_info"]
    assert len(info) == 1
    lbl, v = info[0]
    assert lbl["tenant"] == "0" and lbl["src"] != lbl["dst"]
    assert float(lbl["seq"]) == v
