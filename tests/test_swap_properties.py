"""Live hot-swap properties (hypothesis, shimmed) + the satellite fixes.

Mirrors ``tests/test_core_migration_properties.py`` for the swap path:
where that file pins export/fold/import round trips, this one pins
``EngineCluster.swap_module`` — the paper's kernel-TCP -> mTCP move as a
cluster primitive — under fuzzed timing:

  * a serve-plane swap at an ARBITRARY point in a submit/step sequence
    preserves the carried + live == billed-ground-truth invariant at
    every step, carries each tenant's bucket level/rate/capacity
    bit-for-bit, and drops zero tokens end to end;
  * same for a bytes-plane swap at an arbitrary point in an op stream;
  * swap timing fuzzed against in-flight slots: the quiesce drains
    exactly what was in flight, on the retiring stack, before the
    transfer — and a swap is refused while the engine is the draining
    source of a live migration;
  * the quiesced-destination guard regression (the double-fold edge): a
    freshly built replacement that adopted the retired module's billed
    ground truth via ``inherit_ground_truth`` must still pass the
    guard (ground truth is engine-slot history, not live tenant state),
    while a destination with pre-seeded live counters is refused BY
    NAME;
  * the stack_swap scenario's trace passes tools/check_trace.py's
    swap-lifecycle rule, and the rule is not vacuous (an injected
    dispatch inside the quiesce window fails it).

Runs under real hypothesis when installed, the deterministic fallback of
``tests/_hyp.py`` otherwise.
"""
import importlib.util
import pathlib

import pytest

from _hyp import given, settings, st
from test_placement import FakeEngine, _req, make_fake_cluster

from repro.core.nqe import CommOp
from repro.obs.tracing import trace_to
from repro.serve.replay import (
    TraceReplayer, scenario_spec, stack_swap_events, swap_live_stack,
)

_CHECK_TRACE = pathlib.Path(__file__).resolve().parents[1] \
    / "tools" / "check_trace.py"
_spec = importlib.util.spec_from_file_location("check_trace", _CHECK_TRACE)
check_trace_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace_mod)

_RATES = st.floats(min_value=100.0, max_value=1e4)
_CAPS = st.floats(min_value=10.0, max_value=1e5)
_TOKENS = st.integers(min_value=1, max_value=6)
_SIZES = st.integers(min_value=1, max_value=1 << 16)
# one fuzzed run: a sequence of (tenant, max_new_tokens) submissions,
# stepped once each, with the swap injected at an arbitrary index
_SUBMITS = st.lists(st.tuples(st.integers(min_value=0, max_value=2),
                              _TOKENS),
                    min_size=1, max_size=10)
_SWAP_AT = st.integers(min_value=0, max_value=9)

# FakeEngine billing (mirrors ServeEngine): admit bills prompt(2) + first
# token, each decode step bills 1 — a request costs max_new_tokens + 2
_REQ_COST = 2


@settings(max_examples=25)
@given(submits=_SUBMITS, swap_at=_SWAP_AT, rate=_RATES)
def test_serve_swap_at_arbitrary_point_preserves_everything(submits,
                                                            swap_at, rate):
    """Wherever the swap lands in the submit/step stream: conservation at
    every step, the bucket travels exactly, and zero tokens drop."""
    cl = make_fake_cluster(2)
    for t in range(3):
        cl.add_tenant(t, engine=0)
    cl.engines[0].scheduler.set_rate(0, rate, None, 0.0)
    old_policy = cl.engines[0].scheduler.policy
    expected = {t: 0 for t in range(3)}
    rec = None
    swap_at = min(swap_at, len(submits) - 1)
    for i, (t, tokens) in enumerate(submits):
        now = float(i)
        if i == swap_at:
            b = cl.engines[0].scheduler.buckets[0]
            before = (b.rate, b.capacity, b.snapshot(now=now)["tokens"])
            rec = swap_live_stack(cl, "serve", engine=0, now=now)
            nb = cl.engines[0].scheduler.buckets[0]
            assert (nb.rate, nb.capacity) == before[:2]
            assert nb.snapshot(now=now)["tokens"] == \
                pytest.approx(before[2])
        cl.submit(_req(t, k=i, tokens=tokens, now=now))
        expected[t] += tokens + _REQ_COST
        cl.step(now=now)
        for tt in range(3):
            cl.assert_ledger_conservation(tt)
    assert rec is not None and rec.plane == "serve"
    assert cl.engines[0].scheduler.policy != old_policy
    # drain on the swapped-in stack: every submitted token lands exactly
    # once in the continuous (carried + live) ledger
    for j in range(80):
        cl.step(now=float(len(submits) + j))
    for t in range(3):
        assert cl.tenant_served_tokens(t) == expected[t]
        assert cl.tenant_billed_ground_truth(t) == expected[t]
        cl.assert_ledger_conservation(t)


@settings(max_examples=25)
@given(ops=st.lists(_SIZES, min_size=1, max_size=8), swap_at=_SWAP_AT,
       rate=_RATES, cap=_CAPS)
def test_bytes_swap_at_arbitrary_point_preserves_everything(ops, swap_at,
                                                            rate, cap):
    """Same property one plane down: the CoreEngine swap (native xla <->
    compressed transport) at any point in an op stream."""
    cl = make_fake_cluster(2, core_plane=True)
    cl.add_tenant(1, engine=0)
    cl.core_engines[0].set_tenant_rate(1, rate, burst=cap)
    pumped = 0
    swap_at = min(swap_at, len(ops) - 1)
    rec = None
    for i, sz in enumerate(ops):
        now = float(i)
        if i == swap_at:
            b = cl.core_engines[0].buckets[1]
            before = (b.rate, b.capacity, b.snapshot(now=now)["tokens"])
            rec = swap_live_stack(cl, "bytes", engine=0, now=now)
            nb = cl.core_engines[0].buckets[1]
            assert (nb.rate, nb.capacity) == before[:2]
            assert nb.snapshot(now=now)["tokens"] == \
                pytest.approx(before[2])
        core = cl.core_engines[0]
        op = CommOp(verb="psum", axes=("pod",), tenant_id=1,
                    size_bytes=int(sz))
        core.admit(op, now)
        core.route(op)
        pumped += int(sz)
        assert cl.tenant_core_bytes(1) == pumped
        cl.assert_ledger_conservation(1)
    assert rec is not None and rec.plane == "bytes"
    assert rec.old_stack != rec.new_stack
    bytes_plane = next(p for p in cl.planes if p.name == "bytes")
    assert bytes_plane.ledger.ground_truth(1) == pumped


@settings(max_examples=25)
@given(n_reqs=st.integers(min_value=0, max_value=6),
       pre_steps=st.integers(min_value=0, max_value=4), tokens=_TOKENS)
def test_swap_quiesce_drains_exactly_the_inflight_slots(n_reqs, pre_steps,
                                                        tokens):
    """Fuzz the swap against the slot machinery: whatever is in flight at
    swap time finishes (and bills) on the retiring stack during the
    quiesce; the replacement starts with empty slots; nothing drops."""
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    for r in range(n_reqs):
        cl.submit(_req(0, k=r, tokens=tokens))
    for i in range(pre_steps):
        cl.step(now=float(i))
    inflight = cl.engines[0].inflight()
    rec = swap_live_stack(cl, "serve", engine=0, now=float(pre_steps))
    assert rec.inflight_at_swap == inflight
    assert (rec.quiesce_steps > 0) == (inflight > 0)
    assert cl.engines[0].inflight() == 0
    for j in range(60):
        cl.step(now=float(pre_steps + 1 + j))
    assert cl.tenant_served_tokens(0) == n_reqs * (tokens + _REQ_COST)
    cl.assert_ledger_conservation(0)


def test_swap_refused_while_engine_is_a_draining_source():
    """A drain's residual billing lives on the source module until the
    last slot retires — swapping that module out would strand it."""
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    cl.submit(_req(0, tokens=6))
    cl.step(now=0.0)
    assert cl.engines[0].inflight() == 1
    cl.migrate(0, 1, now=0.1)
    assert cl.draining == {0: 0}
    with pytest.raises(RuntimeError, match="draining source"):
        cl.swap_module(0, "serve", FakeEngine, now=0.2)
    # the drain DESTINATION is not a source — swapping it is legal, and
    # the mid-drain tenant's state rides across the swap
    rec = swap_live_stack(cl, "serve", engine=1, now=0.3)
    assert rec.engine == 1 and 0 in rec.tenants
    for i in range(20):
        cl.step(now=1.0 + i)
    assert not cl.draining
    # drain finalized: the source engine swaps fine now
    rec = swap_live_stack(cl, "serve", engine=0, now=30.0)
    assert rec.engine == 0
    assert cl.tenant_served_tokens(0) == 6 + _REQ_COST
    cl.assert_ledger_conservation(0)


# ---------------------------------------------------------------------------
# the quiesced-destination guard (the double-fold / counter-replay edge)
# ---------------------------------------------------------------------------


def _finished_fake(tokens=3):
    eng = FakeEngine()
    eng.scheduler.add_tenant(1)
    eng.submit(_req(1, tokens=tokens))
    for i in range(tokens + 2):
        eng.step(now=float(i))
    assert eng.inflight() == 0
    return eng


def test_import_refused_on_destination_with_live_counters_by_name():
    """A destination that saw ANY live activity for the tenant — even a
    bare counter, no queue — is refused, and the error names the
    offending state so the operator can see what leaked."""
    src = _finished_fake()
    state = src.export_tenant(1, now=9.0)
    dst = FakeEngine()
    dst.scheduler.account(1, 5)            # pre-seeded live counter
    with pytest.raises(ValueError, match="served_tokens"):
        dst.import_tenant(1, state, now=9.0)


def test_import_accepted_on_replacement_that_inherited_ground_truth():
    """The satellite fix pinned: ``inherit_ground_truth`` hands the
    replacement the retired module's completed records (billed ground
    truth), which must NOT read as live tenant state to the guard — and
    the subsequent import must not replay counters (the double-fold
    would double-bill every carried token)."""
    old = _finished_fake(tokens=3)
    truth = old.billed_ground_truth(1)
    assert truth == 3 + _REQ_COST
    state = old.export_tenant(1, now=9.0)
    new = FakeEngine()
    new.inherit_ground_truth(old)
    assert new.billed_ground_truth(1) == truth
    new.import_tenant(1, state, now=9.0)       # guard must allow this
    # counters start at zero on the new module: the carried side of the
    # ledger remembers, the live side must not replay
    assert new.scheduler.served_tokens.get(1, 0) == 0
    assert new.billed_ground_truth(1) == truth


def test_inherit_ground_truth_refuses_an_unquiesced_module():
    old = FakeEngine()
    old.scheduler.add_tenant(1)
    old.submit(_req(1, tokens=6))
    old.step(now=0.0)
    assert old.inflight() == 1
    with pytest.raises(RuntimeError, match="quiesce"):
        FakeEngine().inherit_ground_truth(old)


def test_swap_into_cluster_does_not_double_fold():
    """Two consecutive swaps of the same slot: each fold carries the live
    counters exactly once — the continuous ledger never jumps."""
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    cl.submit(_req(0, tokens=4))
    for i in range(8):
        cl.step(now=float(i))
    total = cl.tenant_served_tokens(0)
    assert total == 4 + _REQ_COST
    swap_live_stack(cl, "serve", engine=0, now=8.0)
    assert cl.tenant_served_tokens(0) == total
    swap_live_stack(cl, "serve", engine=0, now=9.0)
    assert cl.tenant_served_tokens(0) == total
    assert cl.tenant_billed_ground_truth(0) == total
    cl.assert_ledger_conservation(0)
    assert cl.swaps_total == {"serve": 2}


# ---------------------------------------------------------------------------
# golden stack_swap trace through the swap-lifecycle checker
# ---------------------------------------------------------------------------

GOLDEN_SWAP_LIFECYCLE = [("swap.quiesce", "b"), ("swap.quiesce", "e"),
                         ("swap.transfer", "X"), ("swap.resume", "i")]


def test_stack_swap_trace_passes_the_swap_lifecycle_rule():
    cl = make_fake_cluster(3, core_plane=True)
    trace, cap = scenario_spec("stack_swap", n_tenants=4, intervals=12)
    with trace_to() as tr:
        rep = TraceReplayer(cl, capacity=cap).run(
            trace, events=stack_swap_events(12))
    assert rep.swaps == 2
    assert {r.plane for r in cl.swap_log} == {"serve", "bytes"}
    doc = tr.chrome_trace()
    assert check_trace_mod.check_trace(doc, scenario="stack_swap") == []
    swaps = [(e["name"], e["ph"]) for e in doc["traceEvents"]
             if e.get("name", "").startswith("swap.")]
    assert swaps == GOLDEN_SWAP_LIFECYCLE * 2
    for t in range(4):
        cl.assert_ledger_conservation(t)


def test_swap_lifecycle_rule_is_not_vacuous():
    """The no-dispatch-while-quiesced rule goes by event order (the
    virtual clock makes the window zero-width): inject a dispatch right
    inside the window and the checker must flag it."""
    cl = make_fake_cluster(3, core_plane=True)
    trace, cap = scenario_spec("stack_swap", n_tenants=4, intervals=12)
    with trace_to() as tr:
        TraceReplayer(cl, capacity=cap).run(trace,
                                            events=stack_swap_events(12))
    doc = tr.chrome_trace()
    evs = doc["traceEvents"]
    i = next(i for i, e in enumerate(evs)
             if e.get("name") == "swap.quiesce" and e.get("ph") == "b")
    eng = evs[i]["args"]["engine"]
    tid = next(m["tid"] for m in evs
               if m.get("ph") == "M"
               and (m.get("args") or {}).get("name") == f"engine{eng}")
    evs.insert(i + 1, {"name": "request.dispatch", "ph": "i", "pid": 1,
                       "tid": tid, "ts": evs[i]["ts"], "s": "t"})
    probs = check_trace_mod.check_trace(doc)
    assert any("swap.quiesce window" in p for p in probs)
    # ...and a missing plane fails the scenario requirement
    doc["traceEvents"] = [
        e for e in evs
        if not (e.get("name", "").startswith("swap.")
                and (e.get("args") or {}).get("plane") == "bytes")]
    probs = check_trace_mod.check_trace(doc, scenario="stack_swap")
    assert any("no swap.transfer on plane 'bytes'" in p for p in probs)
