"""MoE dispatch invariants: routing correctness, capacity, combine math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_smoke_config
from repro.distribution.sharding import ShardingCtx
from repro.models.moe import _capacity, _dispatch_tables, apply_moe, route_topk


def test_dispatch_tables_place_tokens_in_their_expert():
    T, k, E = 32, 2, 4
    key = jax.random.PRNGKey(0)
    eidx = jax.random.randint(key, (T, k), 0, E)
    gate = jax.nn.softmax(jax.random.normal(key, (T, k)))
    C = _capacity(T, type("M", (), {"top_k": k, "capacity_factor": 1.25,
                                    "num_experts": E})())
    table, slot_of, w_flat, drop = _dispatch_tables(eidx, gate, E, C, T, k)
    table = np.asarray(table)
    slot_of = np.asarray(slot_of)
    for j in range(T * k):
        t, kk = divmod(j, k)
        e = int(eidx[t, kk])
        s = int(slot_of[j])
        if s < E * C:
            assert s // C == e, "assignment landed in the wrong expert"
            assert table[s] == t, "slot does not point back at the token"
    # every non-sentinel table entry is a real token id
    assert ((table == T) | (table < T)).all()


@given(T=st.sampled_from([8, 32, 64]), E=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 2), seed=st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_dispatch_inverse_consistency(T, E, k, seed):
    key = jax.random.PRNGKey(seed)
    eidx = jax.random.randint(key, (T, k), 0, E)
    gate = jnp.ones((T, k)) / k
    C = T * k  # an expert can receive every assignment: no drops possible
    table, slot_of, w_flat, drop = _dispatch_tables(eidx, gate, E, C, T, k)
    assert float(drop) == 0.0
    # round trip: token -> slot -> table -> token
    slot_of = np.asarray(slot_of)
    table = np.asarray(table)
    tok = np.arange(T * k) // k
    live = slot_of < E * C
    assert (table[slot_of[live]] == tok[live]).all()


def test_moe_matches_dense_expert_loop(mesh1, rcfg_small):
    """Tiny MoE: compare against an explicit per-token loop (no drops)."""
    cfg = get_smoke_config("arctic-480b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0,
                                     parallel_dense=False))
    from repro.distribution.sharding import init_params
    from repro.models.moe import moe_schema
    schema = moe_schema(cfg, mesh1)
    p = init_params(schema, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.float32) * 0.3
    shd = ShardingCtx(mesh1)
    y, aux = apply_moe(p, x.astype(jnp.bfloat16), cfg, shd, rcfg_small)
    # manual reference
    gate, eidx, _ = route_topk(p["router"], x.reshape(8, -1), cfg.moe)
    y_ref = np.zeros((8, cfg.d_model), np.float32)
    xf = np.asarray(x.reshape(8, -1), np.float32)
    for t in range(8):
        for j in range(cfg.moe.top_k):
            e = int(eidx[t, j])
            w_in = np.asarray(p["w_in"][e], np.float32)
            w_gate = np.asarray(p["w_gate"][e], np.float32)
            w_out = np.asarray(p["w_out"][e], np.float32)
            h = xf[t] @ w_in
            g = xf[t] @ w_gate
            silu = g / (1 + np.exp(-g))
            y_ref[t] += float(gate[t, j]) * ((silu * h) @ w_out)
    np.testing.assert_allclose(np.asarray(y[0], np.float32), y_ref,
                               rtol=8e-2, atol=8e-2)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_capacity_drops_are_reported(mesh1, rcfg_small):
    cfg = get_smoke_config("deepseek-v2-236b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    from repro.distribution.sharding import init_params
    from repro.models.moe import moe_schema
    p = init_params(moe_schema(cfg, mesh1), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.bfloat16)
    shd = ShardingCtx(mesh1)
    y, aux = apply_moe(p, x, cfg, shd, rcfg_small)
    assert float(aux["moe_drop_frac"]) > 0.0
