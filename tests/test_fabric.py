"""StackModule protocol: one tenant lifecycle for both planes.

Tier-1, jit-free. Pins the fabric layer (repro.fabric) the cluster and
placement loop are now written against:

  * ``TenantState`` is the uniform transfer unit both planes export;
  * ``ConservationLedger`` is the ONE carried-ledger + conservation
    assert implementation (serve tokens and collective bytes run through
    the same code path);
  * ``CoreEngine.import_tenant`` refuses a destination holding ANY live
    bytes-plane state — not just a bucket (regression: an unbucketed
    tenant with live ledger/deferred entries used to import silently and
    corrupt byte continuity);
  * park is a real suspend/resume: parking drops droppable buffers
    (bytes freed ledger), unparking resumes, and serving state survives.
"""
import pytest

from repro.core.engine import CoreEngine
from repro.core.nqe import CommOp
from repro.fabric import (
    ConservationLedger, SchedulerServeModule, StackModule, TenantLoad,
    TenantState,
)

from test_placement import FakeEngine, _req, make_fake_cluster


def _op(tenant, nbytes=1000):
    return CommOp(verb="psum", axes=("pod",), tenant_id=tenant,
                  size_bytes=nbytes)


def _pump_core(engine, tenant, nbytes, n=1, now=0.0):
    for _ in range(n):
        op = _op(tenant, nbytes)
        engine.admit(op, now)
        engine.route(op)


# ---------------------------------------------------------------------------
# the protocol surface
# ---------------------------------------------------------------------------


def test_both_planes_implement_the_stack_module_protocol():
    """ServeEngine (via SchedulerServeModule), CoreEngine and the
    jit-free fake all implement ONE protocol — the cluster never needs a
    concrete class again."""
    from repro.serve.engine import ServeEngine

    assert issubclass(ServeEngine, StackModule)
    assert issubclass(ServeEngine, SchedulerServeModule)
    assert issubclass(CoreEngine, StackModule)
    assert issubclass(FakeEngine, SchedulerServeModule)
    # the planes pin their ledger vocabulary on the class
    assert ServeEngine.conserved_field == "served_tokens"
    assert CoreEngine.conserved_field == "bytes"
    assert "served_tokens" in ServeEngine.ledger_fields
    assert "bytes" in CoreEngine.ledger_fields


def test_tenant_state_carries_bucket_counters_and_payload():
    st = TenantState(plane="serve", bucket={"rate": 5.0, "capacity": 10.0,
                                            "tokens": 7.5, "updated": 0.0},
                     carried={"served_tokens": 42},
                     payload={"queue": [1, 2], "weight": 2.0})
    assert st.bucket_tokens == 7.5
    assert list(st.queue) == [1, 2]
    uncapped = TenantState(plane="bytes", bucket=None, carried={})
    assert uncapped.bucket_tokens == 0.0
    assert list(uncapped.queue) == []


def test_tenant_load_is_the_placement_signal():
    e = FakeEngine(batch_slots=2)
    e.submit(_req(0, k=0, tokens=6))
    e.submit(_req(0, k=1, tokens=6))
    e.submit(_req(0, k=2, tokens=6))
    e.step(now=0.0)                      # 2 slots admit, 1 stays queued
    tl = e.tenant_load(0)
    assert isinstance(tl, TenantLoad)
    assert tl.pending == 1 and tl.inflight == 2
    assert tl.queued_tokens == 8.0       # prompt(2) + decode(6), charged
    assert tl.inflight_tokens > 0
    assert e.load() == pytest.approx(3.0)
    # a slot whose req was cleared concurrently must not crash the signal
    e.slots[0].req = None
    assert e.inflight(0) == 1
    assert e.tenant_load(0).inflight == 1


# ---------------------------------------------------------------------------
# ConservationLedger: one fold/assert implementation for any plane
# ---------------------------------------------------------------------------


def test_conservation_ledger_folds_and_asserts_across_modules():
    mods = [CoreEngine(enforcement="account") for _ in range(3)]
    led = ConservationLedger(mods)
    assert led.conserved == "bytes"
    _pump_core(mods[0], 1, 500, n=4)
    led.assert_conservation(1)
    assert led.total(1) == 2000
    # export -> fold -> import: carried+live stays pinned to ground truth
    st = mods[0].export_tenant(1, now=0.0)
    led.fold(1, mods[0], st)
    mods[1].import_tenant(1, st, now=0.0)
    assert led.total(1) == 2000
    led.assert_conservation(1)
    _pump_core(mods[1], 1, 300, n=2)
    assert led.total(1) == 2600
    led.assert_conservation(1)
    assert led.merged("bytes")[1] == 2600
    assert led.merged("ops")[1] == 6
    with pytest.raises(KeyError):
        led.merged("no_such_field")
    # a tampered carried view is caught by the SAME assert both planes use
    led.carried["bytes"][1] += 7
    with pytest.raises(AssertionError, match="bytes"):
        led.assert_conservation(1)


def test_serve_and_bytes_planes_share_the_assert_implementation():
    """EngineCluster.assert_ledger_conservation is one loop over planes —
    corrupting EITHER plane's ledger trips the shared assert."""
    cl = make_fake_cluster(2, core_plane=True)
    cl.add_tenant(0, engine=0)
    _pump_core(cl.core_engines[0], 0, 1024, n=3)
    cl.submit(_req(0))
    cl.step(now=0.1)
    cl.assert_ledger_conservation(0)
    serve_led = cl.serve_plane.ledger
    bytes_led = cl.planes[1].ledger
    serve_led.carried["served_tokens"][0] = \
        serve_led.carried["served_tokens"].get(0, 0) + 5
    with pytest.raises(AssertionError, match="serve"):
        cl.assert_ledger_conservation(0)
    serve_led.carried["served_tokens"][0] -= 5
    bytes_led.carried["bytes"][0] = bytes_led.carried["bytes"].get(0, 0) + 5
    with pytest.raises(AssertionError, match="bytes"):
        cl.assert_ledger_conservation(0)


# ---------------------------------------------------------------------------
# satellite regression: quiesced-destination guard covers ALL live state
# ---------------------------------------------------------------------------


def test_core_import_rejects_destination_with_any_live_state():
    """Regression: the guard used to check only ``buckets``, so an
    unbucketed tenant with live ledger/deferred entries on the
    destination imported silently and corrupted byte continuity."""
    src = CoreEngine(enforcement="account")
    src.set_tenant_rate(1, 1000.0)
    _pump_core(src, 1, 100, n=2)
    state = src.export_tenant(1, now=0.0)

    # live route-ledger entries, NO bucket: must refuse
    dst = CoreEngine(enforcement="account")
    _pump_core(dst, 1, 64)
    assert 1 not in dst.buckets
    assert dst.has_tenant(1)
    with pytest.raises(ValueError, match="live bytes-plane state"):
        dst.import_tenant(1, state, now=0.0)

    # live deferred entries only (zero-rate bucket tenant that was then
    # unbucketed): must refuse too
    dst2 = CoreEngine(enforcement="account")
    dst2.set_tenant_rate(1, 0.0, burst=0.0)
    _pump_core(dst2, 1, 64)              # all 64 bytes deferred
    dst2.export_tenant(1, now=0.0)       # cleanly quiesce...
    _pump_core(dst2, 1, 32)              # ...then new live state appears
    with pytest.raises(ValueError):
        dst2.import_tenant(1, state, now=0.0)

    # a genuinely quiesced destination accepts, and continuity holds
    dst3 = CoreEngine(enforcement="account")
    assert not dst3.has_tenant(1)
    dst3.import_tenant(1, state, now=0.0)
    assert dst3.buckets[1].rate == 1000.0


def test_import_refuses_a_cross_plane_tenant_state():
    """Bucket snapshots are shape-identical across planes, so a
    wrong-plane import would silently install a wrong-unit bucket —
    both planes refuse by TenantState.plane instead."""
    from repro.serve.scheduler import TenantScheduler

    sched = TenantScheduler(charge_prompt=True)
    sched.add_tenant(1, rate_tokens_per_s=10.0)
    serve_state = sched.export_tenant(1, now=0.0)

    core = CoreEngine(enforcement="account")
    core.set_tenant_rate(2, 1000.0)
    bytes_state = core.export_tenant(2, now=0.0)

    with pytest.raises(ValueError, match="serve"):
        core.import_tenant(1, serve_state, now=0.0)
    with pytest.raises(ValueError, match="bytes"):
        sched.import_tenant(2, bytes_state, now=0.0)
    # right-plane imports still land
    sched.import_tenant(1, serve_state, now=0.0)
    core.import_tenant(2, bytes_state, now=0.0)
    assert sched.buckets[1].rate == 10.0
    assert core.buckets[2].rate == 1000.0


def test_cluster_migrate_pre_checks_bytes_plane_before_export():
    """The cluster's pre-export quiescence check uses the same
    ``has_tenant`` guard, so a dirty bytes-plane destination aborts the
    move BEFORE the serve queue is destructively exported."""
    cl = make_fake_cluster(2, core_plane=True)
    cl.add_tenant(0, engine=0)
    cl.submit(_req(0))
    # dirty destination: live bytes-plane ledger for tenant 0, no bucket
    _pump_core(cl.core_engines[1], 0, 128)
    with pytest.raises(ValueError, match="bytes-plane"):
        cl.migrate(0, 1, now=0.0)
    # the serve queue never left the source
    assert cl.engines[0].scheduler.pending(0) == 1
    assert cl.placement[0] == 0


# ---------------------------------------------------------------------------
# park = real suspend/resume (the memory-saved claim)
# ---------------------------------------------------------------------------


def test_park_suspends_and_frees_bytes_unpark_resumes():
    cl = make_fake_cluster(3)
    cl.add_tenant(0, engine=0)
    per_engine = FakeEngine.FAKE_CACHE_BYTES
    assert cl.resident_bytes() == 3 * per_engine
    cl.park(1)
    cl.park(2)
    assert cl.engines[1].suspended and cl.engines[2].suspended
    assert cl.engines[1].slots == []             # slot buffers dropped
    assert cl.parked_bytes() == 2 * per_engine
    assert cl.bytes_freed_total == 2 * per_engine
    assert cl.resident_bytes() == per_engine
    # the freed bytes integrate per step, like parked_engine_steps
    cl.submit(_req(0))
    for _ in range(4):
        cl.step(now=0.1)
    assert cl.mem_saved_byte_steps == 4 * 2 * per_engine
    assert cl.mem_saved() == pytest.approx(2 * per_engine)
    counters = cl.counters()
    assert counters["nk_parked_bytes"] == 2 * per_engine
    assert counters["nk_mem_saved_bytes"] == pytest.approx(2 * per_engine)
    assert counters["nk_bytes_freed_total"] == 2 * per_engine
    assert counters["nk_peak_resident_cache_bytes"] == 3 * per_engine
    # unpark resumes: slots come back, residency returns, and the engine
    # serves again with its ledger intact
    cl.unpark(1)
    assert not cl.engines[1].suspended
    assert len(cl.engines[1].slots) == cl.engines[1].B
    assert cl.parked_bytes() == per_engine
    assert cl.resident_bytes() == 2 * per_engine
    rec = cl.migrate(0, 1, now=0.5)
    assert rec is not None
    cl.submit(_req(0, k=1))
    for _ in range(8):
        cl.step(now=0.6)
    cl.assert_ledger_conservation(0)
    assert cl.engines[1].scheduler.served_tokens.get(0, 0) > 0


def test_suspend_refuses_inflight_work_and_is_idempotent():
    e = FakeEngine(batch_slots=2)
    e.submit(_req(0))
    e.step(now=0.0)
    assert e.inflight() > 0
    with pytest.raises(RuntimeError, match="in "):
        e.suspend()
    # drain, then suspend cleanly — twice (idempotent)
    for _ in range(8):
        e.step(now=0.1)
    assert e.inflight() == 0
    assert e.suspend() == FakeEngine.FAKE_CACHE_BYTES
    assert e.suspend() == 0
    assert e.resident_bytes() == 0
    assert e.resume() > 0
    assert e.resume() == 0
    # ground truth survived the suspend/resume cycle
    assert e.billed_ground_truth(0) == e.scheduler.served_tokens[0]


def test_parked_engine_conservation_holds_through_suspend():
    """Suspending drops buffers, never ledgers: conservation (which sums
    completed-request ground truth on the suspended engine) still holds
    after the tenant migrated away and the source parked."""
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    cl.submit(_req(0))
    for _ in range(8):
        cl.step(now=0.1)                 # request completes on engine 0
    cl.migrate(0, 1, now=0.2)
    cl.park(0)                           # source is quiesced: suspend it
    cl.assert_ledger_conservation(0)
    assert cl.tenant_served_tokens(0) == cl.tenant_billed_ground_truth(0)
    assert cl.tenant_served_tokens(0) > 0
