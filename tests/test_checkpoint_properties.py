"""Fabric checkpoint/restore properties (hypothesis, shimmed) + satellites.

Mirrors ``tests/test_swap_properties.py`` for the failover path: where
that file pins ``swap_module`` under fuzzed timing, this one pins
``EngineCluster.checkpoint`` / ``fail_engine`` / ``recover_engine`` /
``restore`` — the kill-and-restore primitive the fleet layer needs
before anyone trusts a cross-cluster drain — at ARBITRARY crash points:

  * a checkpoint -> fail -> recover cycle at any point in a submit/step
    stream is identity on bucket level/rate/capacity and the carried
    ledgers, holds the carried + live == billed-ground-truth invariant
    at every subsequent step, and the drained total equals billed
    ground truth exactly;
  * same one plane down: the bytes-plane CoreEngine at any point in an
    op stream (crash + recover at the checkpoint instant loses nothing
    — collective routing is synchronous);
  * serialization is a byte-stable strict round trip:
    ``from_bytes(to_bytes(s)) == s``, re-encoding reproduces the exact
    bytes, and an unknown ``version`` is rejected by value — at
    ``from_bytes``, at ``restore`` and at ``recover_engine``;
  * restore into a NON-quiesced target is refused: ``recover_engine``
    on a live engine, and ``restore_tenant`` onto a scheduler with any
    live state for the tenant (refused BY NAME — the PR 7 live-counter
    guard pattern), so a second restore after a failed attempt raises
    instead of re-adding counters;
  * the latency-histogram restore REBASELINES (wholesale replace):
    re-importing the same snapshot twice yields the checkpointed
    counts, never doubled ones;
  * the failover scenario's trace passes tools/check_trace.py's
    checkpoint/fail/recover rule, and the rule is not vacuous (a
    dropped recover, a dropped checkpoint, and an injected dispatch on
    the dark engine's track all fail it).

Runs under real hypothesis when installed, the deterministic fallback of
``tests/_hyp.py`` otherwise.
"""
import importlib.util
import json
import pathlib

import pytest

from _hyp import given, settings, st
from test_placement import _req, make_fake_cluster

from repro.core.nqe import CommOp
from repro.fabric import FABRIC_SNAPSHOT_VERSION, FabricSnapshot
from repro.obs.tracing import trace_to
from repro.serve.replay import TraceReplayer, failover_events, scenario_spec

_CHECK_TRACE = pathlib.Path(__file__).resolve().parents[1] \
    / "tools" / "check_trace.py"
_spec = importlib.util.spec_from_file_location("check_trace", _CHECK_TRACE)
check_trace_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace_mod)

_RATES = st.floats(min_value=100.0, max_value=1e4)
_CAPS = st.floats(min_value=10.0, max_value=1e5)
_TOKENS = st.integers(min_value=1, max_value=6)
_SIZES = st.integers(min_value=1, max_value=1 << 16)
# one fuzzed run: a sequence of (tenant, max_new_tokens) submissions,
# stepped once each, with the crash injected at an arbitrary index
_SUBMITS = st.lists(st.tuples(st.integers(min_value=0, max_value=2),
                              _TOKENS),
                    min_size=1, max_size=10)
_CRASH_AT = st.integers(min_value=0, max_value=9)

# FakeEngine billing (mirrors ServeEngine): a request costs
# max_new_tokens + prompt(2)
_REQ_COST = 2


def _serve_state(snap, engine, tenant):
    plane = next(p for p in snap.planes if p.name == "serve")
    return plane.modules[engine].tenants[tenant]


@settings(max_examples=25)
@given(submits=_SUBMITS, crash_at=_CRASH_AT, rate=_RATES)
def test_serve_recover_at_arbitrary_crash_point_is_identity(submits,
                                                            crash_at, rate):
    """Wherever the crash lands: the recovered bucket and carried
    ledgers equal the checkpoint exactly, and conservation holds at
    every step after."""
    cl = make_fake_cluster(2)
    for t in range(3):
        cl.add_tenant(t, engine=0)
    cl.engines[0].scheduler.set_rate(0, rate, None, 0.0)
    crash_at = min(crash_at, len(submits) - 1)
    recovered = False
    for i, (t, tokens) in enumerate(submits):
        now = float(i)
        if i == crash_at:
            snap = cl.checkpoint(now=now)
            b = cl.engines[0].scheduler.buckets[0]
            before = (b.rate, b.capacity, b.snapshot(now=now)["tokens"],
                      {tt: cl.tenant_served_tokens(tt) for tt in range(3)},
                      {tt: cl.tenant_billed_ground_truth(tt)
                       for tt in range(3)})
            rec = cl.fail_engine(0, now=now)
            cl.recover_engine(0, snap, now=now)
            assert rec.recovered and rec.tokens_lost == 0.0
            nb = cl.engines[0].scheduler.buckets[0]
            assert (nb.rate, nb.capacity) == before[:2]
            assert nb.snapshot(now=now)["tokens"] == \
                pytest.approx(before[2])
            for tt in range(3):
                assert cl.tenant_served_tokens(tt) == before[3][tt]
                assert cl.tenant_billed_ground_truth(tt) == before[4][tt]
                cl.assert_ledger_conservation(tt)
            recovered = True
        cl.submit(_req(t, k=i, tokens=tokens, now=now))
        cl.step(now=now)
        for tt in range(3):
            cl.assert_ledger_conservation(tt)
    assert recovered and cl.recoveries_total == 1 and not cl.failed
    # drain on the recovered stack: whatever the crash cost (in-flight
    # remainders are lost by definition), served == billed ground truth
    for j in range(80):
        cl.step(now=float(len(submits) + j))
    for t in range(3):
        assert cl.tenant_served_tokens(t) == \
            cl.tenant_billed_ground_truth(t)
        cl.assert_ledger_conservation(t)


@settings(max_examples=25)
@given(ops=st.lists(_SIZES, min_size=1, max_size=8), crash_at=_CRASH_AT,
       rate=_RATES, cap=_CAPS)
def test_bytes_recover_at_arbitrary_crash_point_is_identity(ops, crash_at,
                                                            rate, cap):
    """Same property one plane down: collective routing is synchronous,
    so a crash at the checkpoint instant loses zero bytes and the
    restored bucket/ledger equal the checkpoint exactly."""
    cl = make_fake_cluster(2, core_plane=True)
    cl.add_tenant(1, engine=0)
    cl.core_engines[0].set_tenant_rate(1, rate, burst=cap)
    pumped = 0
    crash_at = min(crash_at, len(ops) - 1)
    for i, sz in enumerate(ops):
        now = float(i)
        if i == crash_at:
            snap = cl.checkpoint(now=now)
            b = cl.core_engines[0].buckets[1]
            before = (b.rate, b.capacity, b.snapshot(now=now)["tokens"])
            cl.fail_engine(0, now=now)
            assert cl.failed == {0}
            cl.recover_engine(0, snap, now=now)
            nb = cl.core_engines[0].buckets[1]
            assert (nb.rate, nb.capacity) == before[:2]
            assert nb.snapshot(now=now)["tokens"] == \
                pytest.approx(before[2])
            assert cl.tenant_core_bytes(1) == pumped
        core = cl.core_engines[0]
        op = CommOp(verb="psum", axes=("pod",), tenant_id=1,
                    size_bytes=int(sz))
        core.admit(op, now)
        core.route(op)
        pumped += int(sz)
        assert cl.tenant_core_bytes(1) == pumped
        cl.assert_ledger_conservation(1)
    bytes_plane = next(p for p in cl.planes if p.name == "bytes")
    assert bytes_plane.ledger.ground_truth(1) == pumped


@settings(max_examples=25)
@given(submits=_SUBMITS, rate=_RATES)
def test_snapshot_round_trip_is_byte_stable(submits, rate):
    """``from_bytes(to_bytes(s)) == s`` exactly, and re-encoding the
    decoded snapshot reproduces the identical bytes."""
    cl = make_fake_cluster(2, core_plane=True)
    for t in range(3):
        cl.add_tenant(t, engine=t % 2)
    cl.engines[0].scheduler.set_rate(0, rate, None, 0.0)
    for i, (t, tokens) in enumerate(submits):
        cl.submit(_req(t, k=i, tokens=tokens, now=float(i)))
        cl.step(now=float(i))
    snap = cl.checkpoint(now=float(len(submits)))
    data = snap.to_bytes()
    assert snap.to_bytes() == data            # deterministic encoder
    back = FabricSnapshot.from_bytes(data)
    assert back == snap
    assert back.to_bytes() == data            # byte-stable round trip


def test_unknown_snapshot_version_is_rejected_everywhere():
    """Strict-reject by value: at ``from_bytes``, at ``restore`` and at
    ``recover_engine`` (a hand-built snapshot skips ``from_bytes``)."""
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    snap = cl.checkpoint(now=0.0)
    doc = json.loads(snap.to_bytes().decode("utf-8"))
    doc["version"] = FABRIC_SNAPSHOT_VERSION + 1
    tampered = json.dumps(doc).encode("utf-8")
    with pytest.raises(ValueError, match="unknown FabricSnapshot version"):
        FabricSnapshot.from_bytes(tampered)
    snap.version = FABRIC_SNAPSHOT_VERSION + 1
    with pytest.raises(ValueError, match="unknown FabricSnapshot version"):
        cl.restore(snap)
    cl.fail_engine(0, now=1.0)
    with pytest.raises(ValueError, match="unknown FabricSnapshot version"):
        cl.recover_engine(0, snap, now=1.0)


def test_recover_refused_on_a_live_engine():
    """``recover_engine`` installs checkpoint state — pointing it at an
    engine that never failed would double-install over live state."""
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    snap = cl.checkpoint(now=0.0)
    with pytest.raises(ValueError, match="restore"):
        cl.recover_engine(0, snap, now=0.0)


def test_restore_refused_on_non_quiesced_module_by_name():
    """The module-level guard (PR 7's live-counter pattern): any live
    serve-plane state for the tenant refuses the restore, naming the
    offending state."""
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    cl.submit(_req(0, tokens=4))
    for i in range(8):
        cl.step(now=float(i))
    snap = cl.checkpoint(now=8.0)
    state = _serve_state(snap, 0, 0)
    with pytest.raises(ValueError, match="served_tokens"):
        cl.engines[0].restore_tenant(0, state, now=9.0)


def test_double_restore_after_recover_raises_never_readds():
    """The satellite regression: restoring the same TenantState a second
    time after a successful recover must raise (the recovered counters
    are live state now), leaving every counter exactly as restored."""
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    cl.submit(_req(0, tokens=3))
    for i in range(8):
        cl.step(now=float(i))
    snap = cl.checkpoint(now=8.0)
    cl.fail_engine(0, now=8.0)
    cl.recover_engine(0, snap, now=8.0)
    served = cl.tenant_served_tokens(0)
    assert served == 3 + _REQ_COST
    state = _serve_state(snap, 0, 0)
    with pytest.raises(ValueError, match="served_tokens"):
        cl.engines[0].restore_tenant(0, state, now=9.0)
    # and a second recover_engine is refused too: the engine is live
    with pytest.raises(ValueError, match="restore"):
        cl.recover_engine(0, snap, now=9.0)
    assert cl.tenant_served_tokens(0) == served
    assert cl.tenant_billed_ground_truth(0) == served
    cl.assert_ledger_conservation(0)


def test_latency_restore_rebaselines_not_readds():
    """``restore_latency`` is a wholesale REPLACE: importing the same
    checkpointed histogram payload twice yields the checkpointed
    counts, never doubled ones."""
    from test_placement import FakeEngine
    m = FakeEngine()
    hists = m.latency_hists()
    for v in (0.1, 0.2, 0.4):
        hists["nk_ttft_seconds"].observe(7, v)
        hists["nk_e2e_seconds"].observe(7, 2 * v)
    snap = {fam: {t: h.to_payload() for t, h in th.per_tenant.items()}
            for fam, th in hists.items()}
    m.crash()
    assert m.latency_hists()["nk_ttft_seconds"].per_tenant == {}
    m.restore_latency(snap)
    m.restore_latency(snap)                  # the failed-attempt re-run
    for fam in ("nk_ttft_seconds", "nk_e2e_seconds"):
        h = m.latency_hists()[fam].per_tenant[7]
        assert sum(h.counts) == 3            # not 6: rebaselined
        assert h.to_payload() == snap[fam][7]


def test_checkpoint_refused_mid_drain_and_while_failed():
    """A snapshot cannot carry a drain's in-flight residual billing nor
    a failed engine's buffered admission gap; a pre-migration snapshot
    cannot recover a slot the tenant has since left; and the history a
    drained migration left on the crashed source survives the dark
    window (conservation holds while the slot is down)."""
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    stale = cl.checkpoint(now=0.0)
    cl.submit(_req(0, tokens=6))
    cl.step(now=0.0)
    cl.migrate(0, 1, now=0.1)
    assert cl.draining == {0: 0}
    with pytest.raises(RuntimeError, match="mid-drain"):
        cl.checkpoint(now=0.2)
    for i in range(20):
        cl.step(now=1.0 + i)
    assert not cl.draining
    snap = cl.checkpoint(now=25.0)           # post-drain: legal
    cl.fail_engine(0, now=30.0)
    cl.assert_ledger_conservation(0)         # source history preserved
    with pytest.raises(RuntimeError, match="failed engines"):
        cl.checkpoint(now=30.0)
    # the stale snapshot still places tenant 0 on engine 0 — refused
    with pytest.raises(ValueError, match="since the last move"):
        cl.recover_engine(0, stale, now=31.0)
    cl.recover_engine(0, snap, now=31.0)
    cl.assert_ledger_conservation(0)
    cl.checkpoint(now=32.0)                  # recovered: legal again
    assert cl.checkpoints_total == 3


# ---------------------------------------------------------------------------
# golden failover trace through the checkpoint/fail/recover checker rule
# ---------------------------------------------------------------------------


def _failover_trace_doc():
    cl = make_fake_cluster(3, core_plane=True)
    trace, cap = scenario_spec("failover", n_tenants=4, intervals=12)
    with trace_to() as tr:
        rep = TraceReplayer(cl, capacity=cap).run(
            trace, events=failover_events(12))
    return tr.chrome_trace(), rep, cl


def test_failover_trace_passes_the_lifecycle_rule():
    doc, rep, cl = _failover_trace_doc()
    assert rep.checkpoints >= 1 and rep.recoveries == 1
    assert len(cl.failure_log) == 1 and cl.failure_log[0].recovered
    assert check_trace_mod.check_trace(doc, scenario="failover") == []
    for t in range(4):
        cl.assert_ledger_conservation(t)


def test_failover_lifecycle_rule_is_not_vacuous():
    """Event-order rule, virtual clock: a dropped recover, a dropped
    checkpoint, and a dispatch injected onto the dark engine's track
    must each fail the checker."""
    doc, _, _ = _failover_trace_doc()
    evs = doc["traceEvents"]
    probs = check_trace_mod.check_trace(
        {"traceEvents": [e for e in evs if e.get("name") != "recover"]},
        scenario="failover")
    assert any("never recovered" in p for p in probs)
    assert any("failover lifecycle incomplete" in p for p in probs)
    probs = check_trace_mod.check_trace(
        {"traceEvents": [e for e in evs if e.get("name") != "checkpoint"]})
    assert any("no preceding checkpoint" in p for p in probs)
    i = next(i for i, e in enumerate(evs) if e.get("name") == "fail")
    eng = evs[i]["args"]["engine"]
    tid = next(m["tid"] for m in evs
               if m.get("ph") == "M"
               and (m.get("args") or {}).get("name") == f"engine{eng}")
    injected = list(evs)
    injected.insert(i + 1, {"name": "request.dispatch", "ph": "i",
                            "pid": 1, "tid": tid, "ts": evs[i]["ts"],
                            "s": "t"})
    probs = check_trace_mod.check_trace({"traceEvents": injected})
    assert any(f"while engine {eng} is failed" in p for p in probs)
