"""Swap-conformance: the NSM conformance matrix re-run across a live swap.

The paper's hot-swap claim (kernel TCP -> mTCP under an unmodified guest)
is only real if the swapped-in stack is *numerically* the stack the
conformance suite certified — swapping must not perturb the wire
protocol. This suite re-runs every registry-discovered conformance case
(same matrix, same EF-residual-derived tolerances as
test_nsm_conformance) with the twist that the target stack arrives via
``EngineCluster.swap_module`` mid-stream: a native (XLA) CoreEngine
routes traffic first, the live swap replaces it under the tenant, and
the case's verb then executes through the swapped-in engine's routing.

Per case we also pin the bytes-plane ledger across the swap: the bytes
billed pre-swap are carried (fold -> inherit_ground_truth -> import),
post-swap traffic lands on the new module, and carried + live equals
billed ground truth exactly.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from test_nsm_conformance import (
    CASES, _compressed_atol, _ref, _run, _tol, _x,
)
from test_placement import FakeEngine

from repro.core.engine import CoreEngine
from repro.core.nqe import CommOp, payload_bytes
from repro.core.nsm import available_nsms, get_nsm
from repro.serve.cluster import EngineCluster

PRE_OPS = 3          # ops routed through the native stack before the swap
OP_BYTES = 2048


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(2, 2, pod=2)


def _swap_cluster(mesh):
    """One-engine cluster whose bytes plane starts on the native stack."""
    core = CoreEngine(mesh=mesh, default_nsm="xla", enforcement="account")
    cl = EngineCluster([FakeEngine()], core_engines=[core])
    cl.add_tenant(0, engine=0)
    return cl


def _route(engine, verb, axes, size_bytes=OP_BYTES, now=0.0):
    op = CommOp(verb=verb, axes=tuple(axes), tenant_id=0,
                size_bytes=size_bytes)
    engine.admit(op, now)
    return engine.route(op)


@pytest.mark.parametrize(
    "name,verb,axes,dtype", CASES,
    ids=[f"{n}-{v}-{'+'.join(a)}-{jnp.dtype(d).name}"
         for n, v, a, d in CASES])
def test_swapped_in_stack_matches_xla(mesh, name, verb, axes, dtype):
    cl = _swap_cluster(mesh)
    old = cl.core_engines[0]
    for _ in range(PRE_OPS):
        _route(old, verb, axes)
    billed_pre = old.billed_ground_truth(0)
    assert billed_pre == PRE_OPS * OP_BYTES

    rec = cl.swap_module(
        0, "bytes",
        lambda: CoreEngine(mesh=mesh, default_nsm=name,
                           enforcement="account"))
    new = cl.core_engines[0]
    assert new is not old and new.default_nsm == name
    assert rec.old_stack != rec.new_stack
    # pre-swap bytes survived the swap (fold + inherit_ground_truth)
    assert new.billed_ground_truth(0) == billed_pre

    # the case's verb, executed through the swapped-in engine's routing
    x = _x(dtype)
    nsm = _route(new, verb, axes, size_bytes=payload_bytes(x))
    assert nsm is get_nsm(name)
    out = _run(mesh, nsm, verb, axes, x)
    ref = _ref(mesh, verb, axes, dtype, x)

    # same tolerance ladder as the native conformance suite
    if name == "compressed":
        atol = _compressed_atol(mesh, verb, axes, dtype, x, ref)
        if atol is not None:
            np.testing.assert_allclose(out, ref, rtol=0.0, atol=atol)
            _assert_bytes_conserved(cl, billed_pre, payload_bytes(x))
            return
    tol = _tol(name, dtype)
    np.testing.assert_allclose(out, ref, rtol=tol,
                               atol=tol * float(np.abs(ref).max()))
    _assert_bytes_conserved(cl, billed_pre, payload_bytes(x))


def _assert_bytes_conserved(cl, billed_pre, post_bytes):
    plane = next(p for p in cl.planes if p.name == "bytes")
    plane.ledger.assert_conservation(0, plane="bytes")
    assert cl.tenant_core_bytes(0) == billed_pre + post_bytes
    assert cl.tenant_core_bytes(0) == \
        cl.core_engines[0].billed_ground_truth(0)


def test_swap_matrix_covers_every_registered_stack():
    """The swap suite is only exhaustive if it tracks the registry: every
    non-native NSM must appear in the swapped-in-case matrix."""
    assert {n for n, _, _, _ in CASES} == set(available_nsms()) - {"xla"}
