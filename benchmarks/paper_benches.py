"""Benchmarks mirroring the paper's tables/figures (see DESIGN.md §6).

Each function returns a list of (name, us_per_call, derived) rows; run.py
prints them as CSV. Everything runs on host CPU at reduced scale — the
point is the *system* behaviour (ratios, shares, savings), not absolute
wall-clock.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _timeit(fn, n=5, warmup=2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6   # us


# --- Fig 11: NQE switching throughput vs batch size -------------------------


def bench_nqe_switch() -> List[Row]:
    from repro.core import CoreEngine, CommOp
    eng = CoreEngine()
    eng.add_rule("large", lambda op: op.size_bytes > 1 << 20, "hierarchical")
    op = CommOp(verb="psum", axes=("pod",), size_bytes=1 << 22)
    rows = []
    for batch in (1, 4, 8, 64, 256):
        ops = [op] * batch
        us = _timeit(lambda: eng.route_batch(ops), n=20)
        rows.append((f"nqe_switch_batch{batch}", us,
                     f"{batch / us * 1e6:.0f} NQEs/s"))
    return rows


# --- Fig 12: bulk-data path throughput vs message size ----------------------


def bench_memcopy() -> List[Row]:
    rows = []
    for size_kb in (4, 64, 1024, 8192):
        n = size_kb * 1024 // 4
        x = jnp.arange(n, dtype=jnp.float32)
        cp = jax.jit(lambda a: a * 1.0)
        jax.block_until_ready(cp(x))
        us = _timeit(lambda: jax.block_until_ready(cp(x)), n=10)
        gbps = size_kb / 1024 / 1024 / (us / 1e6) * 8
        rows.append((f"memcopy_{size_kb}KB", us, f"{gbps:.2f} Gbit/s host"))
    return rows


# --- Fig 8 / Table 2: multiplexing savings ----------------------------------


def bench_multiplexing() -> List[Row]:
    from repro.serve import bursty_trace, chip_accounting
    rows = []
    for tenants in (3, 16, 64):
        t0 = time.perf_counter()
        acc = chip_accounting(bursty_trace(tenants, seed=1), cap_per_chip=50.0)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"multiplex_{tenants}tenants", us,
                     f"savings={acc['savings_frac']:.0%} "
                     f"({acc['dedicated_chips']}->{acc['shared_chips']} chips)"))
    return rows


# --- Fig 9: entity-level fair sharing ----------------------------------------


def bench_fairshare() -> List[Row]:
    from repro.configs import RunConfig, get_smoke_config
    from repro.launch.mesh import make_single_device_mesh
    from repro.serve import Request, ServeEngine, TenantScheduler
    cfg = get_smoke_config("internlm2-1.8b")
    rcfg = RunConfig(attn_q_block=16, attn_kv_block=16)
    rows = []
    for selfish in (8, 32):
        sched = TenantScheduler(policy="wfq")
        sched.add_tenant(0)
        sched.add_tenant(1)
        eng = ServeEngine(cfg, rcfg, make_single_device_mesh(),
                          batch_slots=2, max_seq=64, scheduler=sched)
        for _ in range(6):
            eng.submit(Request(0, [1, 2], 10))
        for _ in range(selfish):
            eng.submit(Request(1, [3, 4], 10))
        t0 = time.perf_counter()
        for _ in range(30):
            eng.step()
            if sched.pending(0) == 0:
                break
        us = (time.perf_counter() - t0) * 1e6
        s = sched.shares()
        rows.append((f"fairshare_vs_{selfish}flows", us,
                     f"shares {s.get(0, 0):.2f}/{s.get(1, 0):.2f}"))
    return rows


# --- Fig 21: isolation (rate caps + work conservation) -----------------------


def bench_isolation() -> List[Row]:
    from repro.core import TokenBucket
    caps = {"vm1": TokenBucket(1000, 1000), "vm2": TokenBucket(500, 500)}
    capacity = 10000.0
    got = {"vm1": 0.0, "vm2": 0.0, "vm3": 0.0}
    t0 = time.perf_counter()
    for step in range(100):
        now = step * 0.01
        left = capacity * 0.01
        for vm in ("vm1", "vm2"):
            want = left
            take = 0.0
            b = caps[vm]
            b._refill(now)
            take = min(want, b.tokens)
            if take > 0:
                b.consume(take, now)
            got[vm] += take
            left -= take
        got["vm3"] += left        # uncapped tenant is work-conserving
    us = (time.perf_counter() - t0) * 1e6
    return [("isolation_caps", us,
             f"vm1={got['vm1']:.0f}(cap1000) vm2={got['vm2']:.0f}(cap500) "
             f"vm3={got['vm3']:.0f}(rest)")]


# --- Table 3 / Fig 10: stack swap without API change -------------------------


def bench_stack_swap() -> List[Row]:
    """Same attention call, three stacks: naive -> blockwise -> pallas."""
    from repro.kernels import ops
    b, h, s, d = 1, 8, 512, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d), jnp.float32)
    rows = []
    base = None
    for impl in ("ref", "pallas"):
        f = lambda: jax.block_until_ready(
            ops.mha_forward(q, k, v, impl=impl, q_block=128, kv_block=128))
        us = _timeit(f, n=3)
        if base is None:
            base = us
        rows.append((f"stack_swap_attn_{impl}", us, f"{base / us:.2f}x vs ref"))
    # Fig 10: shm elision vs full reduction (trace-level)
    from repro.core import CommOp, get_nsm
    import numpy as _np
    x = jnp.ones((1 << 16,), jnp.float32)
    op = CommOp(verb="psum", axes=("model",), op_data=1)
    shm = get_nsm("shm")
    f_id = jax.jit(lambda a: a * 1.0)
    jax.block_until_ready(f_id(x))
    us_shm = _timeit(lambda: jax.block_until_ready(f_id(x)), n=10)
    rows.append(("shm_fastpath_move", us_shm, "elided collective (identity)"))
    return rows


# --- Table 5: latency distribution -------------------------------------------


def bench_latency() -> List[Row]:
    from repro.configs import RunConfig, get_smoke_config
    from repro.launch.mesh import make_single_device_mesh
    from repro.serve import Request, ServeEngine
    cfg = get_smoke_config("internlm2-1.8b")
    rcfg = RunConfig(attn_q_block=16, attn_kv_block=16)
    eng = ServeEngine(cfg, rcfg, make_single_device_mesh(), batch_slots=4,
                      max_seq=64)
    t0 = time.perf_counter()
    starts = {}
    for i in range(12):
        r = Request(0, [1, 2, 3], 8, req_id=i)
        starts[i] = time.perf_counter()
        eng.submit(r)
    eng.run_until_drained()
    lats = [(r.finish_time - starts[r.req_id]) * 1e3 for r in eng.completed]
    us = (time.perf_counter() - t0) * 1e6
    lats = sorted(lats)
    return [("serve_latency", us,
             f"min={lats[0]:.0f}ms median={lats[len(lats)//2]:.0f}ms "
             f"max={lats[-1]:.0f}ms n={len(lats)}")]


# --- Tables 6/7: overhead of the NetKernel layer ------------------------------


def bench_overhead() -> List[Row]:
    """nk_psum routed through CoreEngine vs raw lax.psum: identical compiled
    artifact (trace-time-only indirection) + dispatch overhead."""
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core import make_engine, nk_psum, use_engine
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    eng = make_engine(mesh, "xla")
    x = jnp.ones((256, 256), jnp.float32)

    def routed(v):
        with use_engine(eng):
            return nk_psum(v, "model")
    f1 = jax.jit(shard_map(routed, mesh=mesh, in_specs=P(), out_specs=P()))
    f2 = jax.jit(shard_map(lambda v: jax.lax.psum(v, "model"), mesh=mesh,
                           in_specs=P(), out_specs=P()))
    same = f1.lower(x).compile().as_text() == f2.lower(x).compile().as_text()
    us1 = _timeit(lambda: jax.block_until_ready(f1(x)), n=20)
    us2 = _timeit(lambda: jax.block_until_ready(f2(x)), n=20)
    return [("netkernel_overhead", us1,
             f"raw={us2:.1f}us identical_hlo={same} "
             f"ratio={us1 / max(us2, 1e-9):.3f}")]


# --- Figs 18-20 / Table 4: scalability ---------------------------------------


def bench_scalability() -> List[Row]:
    """Collective throughput scaling with device count (host devices)."""
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    rows = []
    n_dev = len(jax.devices())
    size = 1 << 20
    for d in (1, 2, 4, 8):
        if d > n_dev:
            break
        mesh = make_host_mesh(1, d)
        x = jnp.ones((d, size // d), jnp.float32)
        f = jax.jit(shard_map(lambda v: jax.lax.psum(v, "model"), mesh=mesh,
                              in_specs=P("model", None),
                              out_specs=P("model", None)))
        jax.block_until_ready(f(x))
        us = _timeit(lambda: jax.block_until_ready(f(x)), n=10)
        gbps = size * 4 / (us / 1e6) / 1e9
        rows.append((f"psum_scaling_{d}dev", us, f"{gbps:.2f} GB/s"))
    return rows


ALL = [
    bench_nqe_switch, bench_memcopy, bench_multiplexing, bench_fairshare,
    bench_isolation, bench_stack_swap, bench_latency, bench_overhead,
    bench_scalability,
]
