"""Paper Figs. 21/22 analog: fair bandwidth sharing on a shared bottleneck.

Three scenarios, all on the virtual-time harness (deterministic, sub-second):

  convergence   N tenants with unequal demands on one bottleneck, enforced
                by two CoreEngines (the distributed case). Claim (a):
                steady-state per-tenant throughput within 10% of the
                weighted max-min fair allocation.
  isolation     one tenant misbehaves (offers 10x the bottleneck). Claim
                (b): every other tenant's served rate degrades < 5% vs its
                isolated baseline (paper Fig. 22: per-VM isolation).
  backfill      a tenant goes idle mid-run. Claim (c): the freed share is
                re-absorbed by backlogged tenants (work conservation) and
                returned when the tenant comes back.

Run: PYTHONPATH=src python benchmarks/bench_fairness.py
Exit status 1 if any claim fails.

``--e2e`` replays the same claims through a *real* ServeEngine — jitted
prefill/decode, WFQ admission, RateController-enforced token buckets — and
measures every number from engine/scheduler ledgers (repro.serve.replay),
plus claim (d): delta-based push issues <= 25% of full-push set_rate calls
on the steady-state trace.

``--e2e --engines N`` additionally drives an N-engine fabric (one shared
controller, operator-controlled placement) through the adversarial window
with a live tenant migration mid-burst: claim (e) — Jain >= 0.95 and
isolation < 5% must hold across the migration, and the migrated tenant's
served-token ledger is conserved (no loss, no double-billing).

``--e2e --engines N --autopilot`` closes the placement loop: claim (f) —
on the ``consolidation`` scenario the PlacementController packs the idle
fleet and parks >= 1 engine (cores saved > 0 AND memory saved > 0: a
parked engine suspends, dropping its KV-cache/slot buffers — reported as
``mem_saved_bytes`` / ``max_parked_bytes`` / peak resident cache bytes),
waking it when load returns; claim (g) — on ``hotspot`` it auto-migrates the developing hog
with Jain >= 0.95 and isolation < 5%, ledger conservation asserted on
every applied plan on BOTH planes (serve tokens and collective bytes —
the cluster runs with a bytes-plane CoreEngine per engine and synthetic
collective traffic), and zero ping-pong moves under hysteresis.

The autopilot suite also measures claim (h) — the flight recorder's
disabled path (null-object tracer behind ``if TRACER.enabled`` guards)
costs < 2% of the mean decode-step time, gated in
benchmarks/bench_thresholds.json — and claim (i): two live stack-module
hot-swaps mid-burst (serve scheduler variant + bytes NSM flip) drop
zero tokens, keep both planes' ledgers conserved, hold Jain >= 0.95,
and bound the p99 e2e blip vs a swap-free baseline; and claim (j): a
fabric checkpoint cadence plus a kill of the hottest engine mid-burst,
recovered from the last snapshot, keeps ZERO conservation violations on
either plane across the crash, bounds the rolled-back work by one
checkpoint interval (tokens by capacity x cadence, bytes by the pump's
cadence volume), and holds Jain >= 0.95; and claim (k): the fabric
watchdog replayed over the gated scenarios is *precise* — steady fires
zero alerts, adversarial pages fairness on the hog and nobody else,
failover fires AND resolves engine-dark, stack_swap raises nothing
fleet-level — and costs < 2% of the watch-free replay wall.

``--json OUT.json`` additionally writes every row, claim and verdict as a
machine-readable document (the bench trajectory artifact CI uploads);
``--smoke`` runs only the autopilot claims on a reduced trace (the CI
bench-smoke job, gated by tools/check_bench.py against
benchmarks/bench_thresholds.json); ``--trace OUT.json`` records one
migration-scenario replay as a Chrome trace-event JSON (validated by
tools/check_trace.py, loadable in Perfetto) — the CI flight-recorder
artifact; ``--swap-trace OUT.json`` records one stack_swap replay
(validated by tools/check_trace.py --scenario stack_swap);
``--failover-trace OUT.json`` records one failover replay — checkpoint
cadence, kill, kill-and-restore recovery — (validated by
tools/check_trace.py --scenario failover); ``--alerts OUT.json`` dumps
every watched scenario's alert outcome and ``--scrapes OUT.txt`` the
failover run's recorded scrape sequence (replayable offline by
tools/nk_watch.py) — both straight from the claim-(k) runs.
"""
from __future__ import annotations

import json
import pathlib
import sys
from typing import Dict

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.control import SharedBottleneckSim, SimTenant  # noqa: E402

CAPACITY = 1_000_000.0      # bottleneck bytes/s
DT = 0.05
T_RUN = 12.0


def run_convergence() -> Dict:
    """3 unequal tenants + 2 engines: converge to weighted max-min fair."""
    tenants = [
        SimTenant(1, demand=0.15 * CAPACITY),            # satisfied
        SimTenant(2, demand=0.90 * CAPACITY),            # greedy
        SimTenant(3, demand=2.00 * CAPACITY),            # greedier
    ]
    sim = SharedBottleneckSim(tenants, CAPACITY, n_engines=2, dt=DT)
    res = sim.run(T_RUN)
    ref = sim.fair_reference()
    rows, worst = [], 0.0
    for t in sorted(ref):
        got = res.served_rate(t)
        err = abs(got - ref[t]) / ref[t]
        worst = max(worst, err)
        rows.append((f"convergence,tenant{t}_served_frac_of_fair",
                     got / ref[t]))
    rows.append(("convergence,max_rel_error", worst))
    rows.append(("convergence,utilization",
                 res.total_served_rate() / CAPACITY))
    return {"rows": rows, "ok": worst < 0.10,
            "claim": f"max deviation from max-min fair {worst:.1%} < 10%"}


def run_isolation() -> Dict:
    """A 10x-overloading tenant must not hurt in-budget tenants (>5%)."""
    normal = {1: 0.20 * CAPACITY, 2: 0.25 * CAPACITY, 3: 0.15 * CAPACITY}
    # isolated baselines: each normal tenant alone on the bottleneck
    base = {}
    for t, d in normal.items():
        sim = SharedBottleneckSim([SimTenant(t, d)], CAPACITY, dt=DT)
        base[t] = sim.run(T_RUN).served_rate(t)
    # shared run with the misbehaving tenant offering 10x capacity
    tenants = [SimTenant(t, d) for t, d in normal.items()]
    tenants.append(SimTenant(9, demand=10.0 * CAPACITY))
    sim = SharedBottleneckSim(tenants, CAPACITY, dt=DT)
    res = sim.run(T_RUN)
    rows, worst = [], 0.0
    for t in normal:
        degr = max(1.0 - res.served_rate(t) / base[t], 0.0)
        worst = max(worst, degr)
        rows.append((f"isolation,tenant{t}_degradation", degr))
    rows.append(("isolation,hog_served_frac_of_capacity",
                 res.served_rate(9) / CAPACITY))
    rows.append(("isolation,max_degradation", worst))
    return {"rows": rows, "ok": worst < 0.05,
            "claim": f"worst in-budget degradation {worst:.2%} < 5%"}


def run_backfill() -> Dict:
    """Idle tenant's share is re-absorbed, then returned when it's back."""
    def on_off(t):
        return 0.8 * CAPACITY if t < 4.0 or t >= 8.0 else 0.0

    tenants = [SimTenant(1, on_off), SimTenant(2, 2.0 * CAPACITY)]
    sim = SharedBottleneckSim(tenants, CAPACITY, dt=DT)
    sim.run(4.0)
    mid = sim.run(4.0)                      # tenant 1 idle
    back = sim.run(4.0)                     # tenant 1 returns
    absorbed = mid.served_rate(2, 0.4, 1.0) / CAPACITY
    returned = back.served_rate(1, 0.5, 1.0) / (0.5 * CAPACITY)
    rows = [("backfill,idle_phase_utilization_by_survivor", absorbed),
            ("backfill,returning_tenant_frac_of_fair", returned)]
    ok = absorbed > 0.90 and abs(returned - 1.0) < 0.15
    return {"rows": rows, "ok": ok,
            "claim": f"survivor absorbed {absorbed:.0%} of capacity; "
                     f"returning tenant at {returned:.0%} of fair share"}


ALL = (run_convergence, run_isolation, run_backfill)


# ---------------------------------------------------------------------------
# End-to-end replays (real ServeEngine; everything read from ledgers)
# ---------------------------------------------------------------------------

E2E_TENANTS = 4
E2E_INTERVALS = 18

# control-plane backend for every e2e engine/cluster this process builds:
# "object" (per-tenant Python state) or "vectorized" (flat-array telemetry
# banks, BucketStore admission buckets, the fused jitted water-fill).
# Set by --backend; the e2e claims must hold under either.
BACKEND = "object"


def _e2e_report(trace, capacity, push_mode="full"):
    from repro.serve.replay import TraceReplayer, make_replay_engine
    eng = make_replay_engine(capacity=capacity, push_mode=push_mode,
                             backend=BACKEND)
    return TraceReplayer(eng, capacity=capacity).run(trace)


def run_e2e_convergence() -> Dict:
    """Claim (a) on the real datapath: Jain >= 0.95 and <10% max-min
    deviation, from ServeEngine ledgers."""
    from repro.serve.replay import scenario_spec
    trace, cap = scenario_spec("steady", n_tenants=E2E_TENANTS,
                               intervals=E2E_INTERVALS)
    rep = _e2e_report(trace, cap)
    jain, dev = rep.jain(), rep.max_min_deviation()
    rows = [("e2e_convergence,jain_index", jain),
            ("e2e_convergence,max_min_deviation", dev),
            ("e2e_convergence,utilization", rep.total_rate() / cap),
            ("e2e_convergence,decode_steps", float(rep.decode_steps))]
    for t, r in sorted(rep.per_tenant.items()):
        rows.append((f"e2e_convergence,tenant{t}_tokens_per_s",
                     r.achieved_rate))
    return {"rows": rows, "ok": jain >= 0.95 and dev < 0.10,
            "claim": f"ledger-measured Jain {jain:.3f} >= 0.95, "
                     f"max-min deviation {dev:.1%} < 10%"}


def run_e2e_isolation() -> Dict:
    """Claim (b) on the real datapath: 10x misbehaver, in-budget tenants
    degrade < 5% vs their hog-free baseline."""
    from repro.serve.replay import adversarial_baseline, scenario_spec
    n = E2E_TENANTS
    hog_trace, cap = scenario_spec("adversarial", n_tenants=n,
                                   intervals=E2E_INTERVALS)
    base_trace = adversarial_baseline(hog_trace)
    base = _e2e_report(base_trace, cap)
    shared = _e2e_report(hog_trace, cap)
    rows, worst = [], 0.0
    for t in range(n - 1):
        degr = max(1.0 - shared.per_tenant[t].achieved_rate
                   / base.per_tenant[t].achieved_rate, 0.0)
        worst = max(worst, degr)
        rows.append((f"e2e_isolation,tenant{t}_degradation", degr))
        rows.append((f"e2e_isolation,tenant{t}_p99_admit_wait_s",
                     shared.per_tenant[t].p99_admit_wait_s))
    hog = shared.per_tenant[n - 1]
    rows.append(("e2e_isolation,hog_served_frac_of_capacity",
                 hog.achieved_rate / cap))
    rows.append(("e2e_isolation,hog_mean_admit_wait_s",
                 hog.mean_admit_wait_s))
    rows.append(("e2e_isolation,max_degradation", worst))
    return {"rows": rows, "ok": worst < 0.05,
            "claim": f"worst in-budget degradation {worst:.2%} < 5% "
                     f"(real engine, hog held to "
                     f"{hog.achieved_rate / cap:.0%} of capacity)"}


def run_e2e_delta_push() -> Dict:
    """Claim (d): delta push issues <= 25% of full-push set_rate calls on
    the steady-state trace, with no enforcement quality loss."""
    from repro.serve.replay import scenario_spec
    trace, cap = scenario_spec("steady", n_tenants=E2E_TENANTS,
                               intervals=E2E_INTERVALS)
    full = _e2e_report(trace, cap, push_mode="full")
    delta = _e2e_report(trace, cap, push_mode="delta")
    frac = delta.set_rate_calls / max(full.set_rate_calls, 1)
    rows = [("e2e_delta_push,full_set_rate_calls",
             float(full.set_rate_calls)),
            ("e2e_delta_push,delta_set_rate_calls",
             float(delta.set_rate_calls)),
            ("e2e_delta_push,delta_frac_of_full", frac),
            ("e2e_delta_push,delta_jain", delta.jain())]
    ok = frac <= 0.25 and delta.jain() >= 0.95 \
        and delta.max_min_deviation() < 0.10
    return {"rows": rows, "ok": ok,
            "claim": f"delta push used {frac:.1%} of full-push set_rate "
                     f"calls ({delta.set_rate_calls} vs "
                     f"{full.set_rate_calls}), Jain {delta.jain():.3f}"}


def run_e2e_multi_engine(engines: int = 3) -> Dict:
    """Claims (a)+(b) on a multi-engine fabric, with a live migration.

    N ServeEngines share ONE RateController (one tokens/s bottleneck
    spanning the cluster). The adversarial 10x hog heats its engine;
    mid-window the operator rebalances — a live tenant migration whose
    served-token ledger must be conserved (no loss, no double-billing)
    while Jain stays >= 0.95 and in-budget degradation stays < 5% vs the
    hog-free baseline on the same cluster shape.
    """
    from repro.serve.replay import (
        TraceReplayer, adversarial_baseline, make_replay_cluster,
        scenario_spec,
    )
    n = E2E_TENANTS
    trace, cap = scenario_spec("migration", n_tenants=n,
                               intervals=E2E_INTERVALS)
    base_trace = adversarial_baseline(trace)

    def run(tr, events=None):
        cl = make_replay_cluster(capacity=cap, engines=engines,
                                 backend=BACKEND)
        return TraceReplayer(cl, capacity=cap).run(tr, events=events), cl

    base, _ = run(base_trace)
    moved: Dict = {}

    def rebalance_event(cl, now):
        from repro.serve.replay import operator_rebalance
        rec = operator_rebalance(cl, now=now)
        if rec is not None:
            moved["rec"] = rec
            moved["ledger_at_move"] = cl.tenant_served_tokens(rec.tenant)

    shared, cl = run(trace, events=[(E2E_INTERVALS // 2, rebalance_event)])
    rows, worst = [], 0.0
    for t in range(n - 1):
        degr = max(1.0 - shared.per_tenant[t].achieved_rate
                   / base.per_tenant[t].achieved_rate, 0.0)
        worst = max(worst, degr)
        rows.append((f"e2e_multi,tenant{t}_degradation", degr))
    jain = shared.jain()
    rec = moved.get("rec")
    conserved = False
    if rec is not None:
        final = cl.tenant_served_tokens(rec.tenant)
        truth = cl.tenant_billed_ground_truth(rec.tenant)
        conserved = (final == truth
                     and final >= moved["ledger_at_move"])
        rows.append((f"e2e_multi,migrated_tenant", float(rec.tenant)))
        rows.append(("e2e_multi,migration_queued_moved",
                     float(rec.queued_moved)))
        rows.append(("e2e_multi,migrated_ledger_tokens", float(final)))
        rows.append(("e2e_multi,migrated_ground_truth_tokens",
                     float(truth)))
    rows += [("e2e_multi,engines", float(shared.engines)),
             ("e2e_multi,live_migrations", float(shared.migrations)),
             ("e2e_multi,jain_index", jain),
             ("e2e_multi,max_degradation", worst),
             ("e2e_multi,ledger_conserved", 1.0 if conserved else 0.0)]
    ok = (jain >= 0.95 and worst < 0.05 and shared.migrations >= 1
          and conserved)
    return {"rows": rows, "ok": ok,
            "claim": f"{engines}-engine fabric: Jain {jain:.3f} >= 0.95, "
                     f"worst degradation {worst:.2%} < 5%, "
                     f"{shared.migrations} live migration(s) with the "
                     f"served-token ledger conserved"}


E2E = (run_e2e_convergence, run_e2e_isolation, run_e2e_delta_push)


# ---------------------------------------------------------------------------
# Closed-loop placement (the autopilot claims)
# ---------------------------------------------------------------------------


def _autopilot_cluster(capacity, engines, policy):
    """An N-engine replay cluster with the placement loop closed AND a
    bytes-plane CoreEngine per engine, so every applied plan moves (and
    conservation-checks) both planes."""
    from repro.serve.replay import make_replay_cluster
    return make_replay_cluster(capacity=capacity, engines=engines,
                               autopilot=policy, core_plane=True,
                               backend=BACKEND)


def _byte_pump(cluster, op_bytes=4096):
    """(events, pumped) — per-interval synthetic collective traffic: each
    tenant pushes one CommOp through its placed engine's CoreEngine, so
    the bytes plane has live state for every migration to carry. Tenants
    placed on a *failed* engine are skipped AND not counted — ``pumped``
    tracks bytes actually routed, the quantity conservation is judged
    against (a dark slot takes no collective traffic)."""
    from repro.core.nqe import CommOp

    pumped: Dict[int, int] = {}

    def pump(cl, now):
        failed = getattr(cl, "failed", ())
        for t, k in sorted(cl.placement.items()):
            if k in failed:
                continue
            ce = cl.core_engines[k]
            op = CommOp(verb="psum", axes=("pod",), tenant_id=t,
                        size_bytes=op_bytes)
            ce.admit(op, now)
            ce.route(op)
            pumped[t] = pumped.get(t, 0) + op_bytes
    return pump, pumped


def _conservation_rows(prefix, cluster, pumped, n_tenants):
    """Serve-plane ledger == request ground truth AND bytes-plane carried
    + live == total pumped, for every tenant. Asserted per plane so a
    failure row names the plane that actually broke. Returns
    (rows, all_ok)."""
    ok = {"serve": True, "bytes": True}
    for t in range(n_tenants):
        for plane in cluster.planes:
            try:
                plane.ledger.assert_conservation(t, plane=plane.name)
            except AssertionError:
                ok[plane.name] = False
        if cluster.tenant_core_bytes(t) != pumped.get(t, 0):
            ok["bytes"] = False
    serve_ok, bytes_ok = ok["serve"], ok["bytes"]
    rows = [(f"{prefix},serve_ledger_conserved", 1.0 if serve_ok else 0.0),
            (f"{prefix},bytes_ledger_conserved", 1.0 if bytes_ok else 0.0)]
    return rows, serve_ok and bytes_ok


def _ping_pong_free(cluster) -> float:
    try:
        cluster.autopilot.assert_no_ping_pong()
        return 1.0
    except AssertionError:
        return 0.0


def run_e2e_consolidation(engines: int = 3,
                          intervals: int = E2E_INTERVALS) -> Dict:
    """Claim (f): the closed placement loop consolidates an idle fleet.

    Busy -> idle window -> busy. The ``consolidate`` policy packs the
    idle tenants onto one engine and parks the rest — saving cores (the
    paper's multiplexing claim, closed-loop) AND memory (parked engines
    suspend: KV-cache and slot buffers dropped, lazily re-materialized
    on unpark) — wakes them when load returns, never ping-pongs a
    tenant, and conserves both planes' ledgers on every applied plan.
    """
    from repro.serve.replay import TraceReplayer, scenario_spec
    n = E2E_TENANTS
    trace, cap = scenario_spec("consolidation", n_tenants=n,
                               intervals=intervals)
    cl = _autopilot_cluster(cap, engines, "consolidate")
    pump, pumped = _byte_pump(cl)
    events = [(i, pump) for i in range(intervals)]
    rep = TraceReplayer(cl, capacity=cap).run(trace, events=events)
    jain = rep.jain()
    pp_free = _ping_pong_free(cl)
    cons_rows, conserved = _conservation_rows("e2e_consolidation", cl,
                                              pumped, n)
    rows = [("e2e_consolidation,jain_index", jain),
            ("e2e_consolidation,cores_saved", rep.cores_saved),
            ("e2e_consolidation,max_parked", float(rep.max_parked)),
            ("e2e_consolidation,mem_saved_bytes", rep.mem_saved_bytes),
            ("e2e_consolidation,max_parked_bytes",
             float(rep.max_parked_bytes)),
            ("e2e_consolidation,peak_resident_cache_bytes",
             float(rep.peak_resident_cache_bytes)),
            ("e2e_consolidation,autopilot_moves",
             float(rep.autopilot_moves)),
            ("e2e_consolidation,live_migrations", float(rep.migrations)),
            ("e2e_consolidation,parked_at_end", float(len(cl.parked))),
            ("e2e_consolidation,ping_pong_free", pp_free)] + cons_rows
    ok = (jain >= 0.95 and rep.cores_saved > 0 and rep.max_parked >= 1
          and rep.mem_saved_bytes > 0 and rep.max_parked_bytes > 0
          and pp_free == 1.0 and conserved)
    return {"rows": rows, "ok": ok,
            "claim": f"autopilot parked {rep.max_parked} engine(s) in the "
                     f"idle window (avg {rep.cores_saved:.2f} cores and "
                     f"{rep.mem_saved_bytes / 1024:.0f} KiB saved/step, "
                     f"peak {rep.max_parked_bytes / 1024:.0f} KiB freed), "
                     f"Jain {jain:.3f} >= 0.95, "
                     f"{rep.autopilot_moves} moves, 0 ping-pong, both "
                     f"planes conserved"}


def run_e2e_hotspot(engines: int = 3,
                    intervals: int = E2E_INTERVALS) -> Dict:
    """Claim (g): the closed placement loop auto-migrates a developing hog.

    Everyone equal until a third of the way in, then one tenant turns
    10x. ``spread_hot`` detects the heating engine and migrates the hog
    (and nothing twice) on its own; isolation (< 5% vs the hog-free
    baseline on the same autopilot cluster shape) and Jain >= 0.95 hold
    across the automatic migration; both planes' ledgers are conserved.
    """
    from repro.serve.replay import (
        TraceReplayer, adversarial_baseline, scenario_spec,
    )
    n = E2E_TENANTS
    trace, cap = scenario_spec("hotspot", n_tenants=n, intervals=intervals)
    base_trace = adversarial_baseline(trace)

    def run(tr):
        cl = _autopilot_cluster(cap, engines, "spread_hot")
        pump, pumped = _byte_pump(cl)
        events = [(i, pump) for i in range(tr.loads.shape[1])]
        return TraceReplayer(cl, capacity=cap).run(tr, events=events), \
            cl, pumped

    base, _, _ = run(base_trace)
    shared, cl, pumped = run(trace)
    hog = n - 1
    rows, worst = [], 0.0
    for t in range(n - 1):
        degr = max(1.0 - shared.per_tenant[t].achieved_rate
                   / base.per_tenant[t].achieved_rate, 0.0)
        worst = max(worst, degr)
        rows.append((f"e2e_hotspot,tenant{t}_degradation", degr))
        rows.append((f"e2e_hotspot,tenant{t}_p99_admit_wait_s",
                     shared.per_tenant[t].p99_admit_wait_s))
        rows.append((f"e2e_hotspot,tenant{t}_p99_e2e_s",
                     shared.per_tenant[t].p99_e2e_s))
    jain = shared.jain()
    moved = [mv.tenant for _, mv in cl.autopilot.move_log]
    hog_moved = 1.0 if moved.count(hog) >= 1 else 0.0
    pp_free = _ping_pong_free(cl)
    cons_rows, conserved = _conservation_rows("e2e_hotspot", cl, pumped, n)
    rows += [("e2e_hotspot,jain_index", jain),
             ("e2e_hotspot,max_degradation", worst),
             ("e2e_hotspot,hog_auto_migrated", hog_moved),
             ("e2e_hotspot,autopilot_moves",
              float(shared.autopilot_moves)),
             ("e2e_hotspot,live_migrations", float(shared.migrations)),
             ("e2e_hotspot,ping_pong_free", pp_free)] + cons_rows
    ok = (hog_moved == 1.0 and worst < 0.05 and jain >= 0.95
          and pp_free == 1.0 and conserved)
    return {"rows": rows, "ok": ok,
            "claim": f"autopilot migrated the hog on its own "
                     f"({shared.autopilot_moves} move(s), 0 ping-pong), "
                     f"Jain {jain:.3f} >= 0.95, worst in-budget "
                     f"degradation {worst:.2%} < 5%, both planes "
                     f"conserved"}


def run_e2e_stack_swap(engines: int = 3,
                       intervals: int = E2E_INTERVALS) -> Dict:
    """Claim (i): a live stack hot-swap under traffic drops nothing.

    The adversarial window replayed twice on the same cluster shape
    (bytes-plane CoreEngine per engine, synthetic collective traffic):
    once untouched (the baseline), once with two live stack-module
    swaps mid-burst — the hottest serve engine's module replaced by one
    running the alternate scheduler policy a third of the way in, the
    bytes-plane CoreEngine flipped to the alternate NSM stack two
    thirds in. Gated: >= 2 swaps happened, the served-token ledger
    still equals billed ground truth for every tenant (zero dropped /
    double-billed tokens), both planes' conservation holds, Jain >=
    0.95 across the swaps, and the worst per-tenant p99 e2e latency
    blip vs the swap-free baseline stays bounded.
    """
    from repro.serve.replay import (
        TraceReplayer, make_replay_cluster, scenario_spec, swap_live_stack,
    )
    n = E2E_TENANTS
    trace, cap = scenario_spec("stack_swap", n_tenants=n,
                               intervals=intervals)

    def run(with_swaps):
        cl = make_replay_cluster(capacity=cap, engines=engines,
                                 core_plane=True, backend=BACKEND)
        pump, pumped = _byte_pump(cl)
        events = [(i, pump) for i in range(intervals)]
        if with_swaps:
            serve_at = max(intervals // 3, 1)
            bytes_at = max(2 * intervals // 3, serve_at + 1)
            events += [
                (serve_at,
                 lambda c, now: swap_live_stack(c, "serve", now=now)),
                (bytes_at,
                 lambda c, now: swap_live_stack(c, "bytes", now=now))]
        rep = TraceReplayer(cl, capacity=cap).run(trace, events=events)
        return rep, cl, pumped

    base, _, _ = run(False)
    rep, cl, pumped = run(True)
    dropped = 0.0
    for t in range(n):
        dropped += abs(cl.tenant_served_tokens(t)
                       - cl.tenant_billed_ground_truth(t))
    blip = max(max(rep.per_tenant[t].p99_e2e_s
                   - base.per_tenant[t].p99_e2e_s, 0.0)
               for t in range(n))
    jain = rep.jain()
    cons_rows, conserved = _conservation_rows("e2e_stack_swap", cl,
                                              pumped, n)
    quiesce_steps = sum(s.quiesce_steps for s in cl.swap_log)
    rows = [("e2e_stack_swap,live_swaps", float(rep.swaps)),
            ("e2e_stack_swap,jain_index", jain),
            ("e2e_stack_swap,dropped_tokens", dropped),
            ("e2e_stack_swap,p99_blip_s", blip),
            ("e2e_stack_swap,quiesce_steps", float(quiesce_steps))] \
        + cons_rows
    ok = (rep.swaps >= 2 and jain >= 0.95 and dropped == 0.0
          and conserved and blip <= 2.0)
    return {"rows": rows, "ok": ok,
            "claim": f"{rep.swaps} live stack swap(s) under the "
                     f"adversarial burst ({quiesce_steps} quiesce "
                     f"step(s)): 0 dropped tokens, both planes "
                     f"conserved, Jain {jain:.3f} >= 0.95, worst p99 "
                     f"blip {blip:.3f}s <= 2s"}


def run_e2e_failover(engines: int = 3,
                     intervals: int = E2E_INTERVALS) -> Dict:
    """Claim (j): kill-and-restore loses at most one checkpoint interval.

    The adversarial window on the claim-(i) cluster shape (bytes-plane
    CoreEngine per engine, synthetic collective traffic) with the
    failover drill riding on top: a fabric checkpoint every
    ``FAILOVER_CHECKPOINT_EVERY`` intervals, the hottest engine killed
    mid-burst — deliberately OFF the checkpoint cadence, so real work
    sits between the last snapshot and the kill — and recovered from
    that snapshot two intervals later with the buffered admission gap
    replayed. Gated: >= 1 checkpoint and >= 1 recovery happened, ZERO
    conservation violations on either plane across the crash (restored
    counters equal restored ground truth exactly, for every tenant),
    the work the restore rolled back is bounded by one checkpoint
    interval (tokens by capacity x cadence seconds; bytes by the pump's
    per-tenant cadence volume), and Jain >= 0.95 across the crash.
    """
    from repro.serve.replay import (
        FAILOVER_CHECKPOINT_EVERY, TraceReplayer, failover_events,
        make_replay_cluster, scenario_spec,
    )
    n = E2E_TENANTS
    trace, cap = scenario_spec("failover", n_tenants=n,
                               intervals=intervals)
    cl = make_replay_cluster(capacity=cap, engines=engines,
                             core_plane=True, backend=BACKEND)
    op_bytes = 4096
    pump, pumped = _byte_pump(cl, op_bytes=op_bytes)
    rep = TraceReplayer(cl, capacity=cap).run(
        trace, events=failover_events(intervals, pump=pump))

    # conservation across the crash: the stack_swap equality
    # tenant_core_bytes == pumped does NOT apply here — bytes routed
    # between the last checkpoint and the kill are legitimately rolled
    # back by the restore. Instead: both planes' ledgers must balance
    # exactly (zero violations), and the per-tenant rollback must fit
    # inside one checkpoint interval of pump traffic.
    ok = {"serve": True, "bytes": True}
    bytes_budget = FAILOVER_CHECKPOINT_EVERY * op_bytes
    rolled = 0.0
    for t in range(n):
        for plane in cl.planes:
            try:
                plane.ledger.assert_conservation(t, plane=plane.name)
            except AssertionError:
                ok[plane.name] = False
        gap = pumped.get(t, 0) - cl.tenant_core_bytes(t)
        rolled += max(gap, 0.0)
        if gap < 0 or gap > bytes_budget:
            ok["bytes"] = False
    serve_ok, bytes_ok = ok["serve"], ok["bytes"]

    # token loss, measured by the recovery itself (ground truth at the
    # crash minus ground truth restored), bounded by one checkpoint
    # interval of cluster capacity (trace intervals are 1 virtual s)
    recs = [r for r in cl.failure_log if r.recovered]
    tokens_lost = sum(r.tokens_lost for r in recs)
    token_budget = FAILOVER_CHECKPOINT_EVERY * 1.0 * cap
    loss_frac = tokens_lost / token_budget
    jain = rep.jain()
    rows = [("e2e_failover,checkpoints", float(rep.checkpoints)),
            ("e2e_failover,recoveries", float(rep.recoveries)),
            ("e2e_failover,jain_index", jain),
            ("e2e_failover,tokens_lost", tokens_lost),
            ("e2e_failover,tokens_lost_frac_of_budget", loss_frac),
            ("e2e_failover,bytes_rolled_back", rolled),
            ("e2e_failover,serve_ledger_conserved",
             1.0 if serve_ok else 0.0),
            ("e2e_failover,bytes_ledger_conserved",
             1.0 if bytes_ok else 0.0)]
    ok_all = (rep.checkpoints >= 1 and rep.recoveries >= 1
              and jain >= 0.95 and serve_ok and bytes_ok
              and tokens_lost >= 0.0 and loss_frac <= 1.0)
    return {"rows": rows, "ok": ok_all,
            "claim": f"{rep.recoveries} kill-and-restore(s) under the "
                     f"adversarial burst ({rep.checkpoints} "
                     f"checkpoint(s)): both planes conserved, "
                     f"{tokens_lost:.0f} tokens lost <= one checkpoint "
                     f"interval ({token_budget:.0f}), Jain {jain:.3f} "
                     f">= 0.95"}


SMOKE_INTERVALS = 12


# ---------------------------------------------------------------------------
# Flight-recorder overhead (claim: tracing off is free)
# ---------------------------------------------------------------------------


def run_tracer_overhead(intervals: int = SMOKE_INTERVALS) -> Dict:
    """Claim (h): with tracing disabled, the flight recorder costs nothing.

    Every instrumentation site is guarded by ``if tracing.TRACER.enabled``
    against a null-object tracer, so the disabled path is one module-attr
    load and a branch. This bench measures that guard directly (micro
    loop), counts how many trace points a real replayed decode step
    actually hits (enabled run over the steady scenario), and bounds the
    disabled-path overhead as a fraction of the measured mean step time:

        disabled_step_overhead_frac = guard_ns * events_per_step
                                      / mean_step_ns

    Gated at < 2% in bench_thresholds.json — the machine-independent form
    of "tokens/s regresses < 2% with tracing disabled" (overhead per step
    below 2% of step time bounds the throughput regression at 2%),
    robust to CI runner speed where a raw wall tokens/s floor is not.
    """
    import time

    from repro.obs import tracing
    from repro.serve.replay import scenario_spec

    if tracing.TRACER.enabled:
        return {"rows": [], "ok": False,
                "claim": "tracer unexpectedly enabled at bench start"}

    # 1. the disabled guard, measured directly (exactly the hot-site
    # pattern: module attr load, .enabled load, branch)
    n = 200_000
    t0 = time.perf_counter()
    hits = 0
    for _ in range(n):
        if tracing.TRACER.enabled:
            hits += 1
    guard_ns = (time.perf_counter() - t0) / n * 1e9
    assert hits == 0

    # 2. mean step time on the real datapath, tracer disabled. First run
    # warms the jit caches; the second, on a fresh engine with identical
    # shapes, times the steady-state step.
    trace, cap = scenario_spec("steady", n_tenants=E2E_TENANTS,
                               intervals=intervals)
    _e2e_report(trace, cap)
    t0 = time.perf_counter()
    rep = _e2e_report(trace, cap)
    wall_s = time.perf_counter() - t0
    steps = max(rep.decode_steps, 1)
    mean_step_s = wall_s / steps
    tokens_per_s_wall = sum(r.served_tokens
                            for r in rep.per_tenant.values()) / wall_s

    # 3. trace points per step, counted from an enabled run of the same
    # scenario (arrival/admit/dispatch/finish + control-plane instants)
    from repro.obs.tracing import trace_to
    with trace_to() as tr:
        _e2e_report(trace, cap)
    events_per_step = len(tr.events) / steps

    frac = guard_ns * 1e-9 * events_per_step / mean_step_s
    rows = [("tracer_overhead,disabled_guard_ns", guard_ns),
            ("tracer_overhead,events_per_step", events_per_step),
            ("tracer_overhead,mean_step_us", mean_step_s * 1e6),
            ("tracer_overhead,tokens_per_s_wall", tokens_per_s_wall),
            ("tracer_overhead,disabled_step_overhead_frac", frac)]
    return {"rows": rows, "ok": frac < 0.02,
            "claim": f"disabled-path guard {guard_ns:.0f}ns x "
                     f"{events_per_step:.1f} trace points/step = "
                     f"{frac:.5%} of the {mean_step_s * 1e6:.0f}us mean "
                     f"step (< 2%): tracing off is free"}


# ---------------------------------------------------------------------------
# Watchdog alert precision (claim: it pages on real incidents, only those)
# ---------------------------------------------------------------------------


# claim (k) stashes its watched reports here so --alerts/--scrapes can
# dump artifacts without re-running the scenarios
_WATCHDOG_REPORTS: Dict[str, object] = {}


def run_e2e_watchdog(engines: int = 3,
                     intervals: int = SMOKE_INTERVALS) -> Dict:
    """Claim (k): the fabric watchdog is precise — and nearly free.

    The four gated scenarios replayed with the watchdog attached
    (scraped at every interval boundary, stock rule catalog):

      * ``steady`` fires ZERO alerts — the false-positive gate;
      * ``adversarial`` fires the fairness burn-rate page on the hog,
        and no alert of any kind names another tenant;
      * ``failover`` fires engine-dark while the killed engine is down
        AND resolves it after the kill-and-restore recovery;
      * ``stack_swap`` stays quiet outside the quiesce window: no
        engine-dark, no telemetry-stalled, no conservation/fairness-
        floor/parked-leak pages (the hog's own admit-wait/fairness
        alerts are the adversarial burst's, not the swap's).

    Plus the overhead gate: the watchdog's per-tick cost (scrape ->
    ingest -> full rule evaluation, measured directly) x ticks must
    stay under 2% of the watch-free replay wall — the machine-
    independent form of "watchdog on regresses tokens/s < 2%".
    """
    import time

    from repro.serve.replay import replay_scenario

    n = E2E_TENANTS
    hog = str(n - 1)

    t0 = time.perf_counter()
    replay_scenario("steady", n_tenants=n, intervals=intervals,
                    backend=BACKEND)
    base_wall = time.perf_counter() - t0
    steady = replay_scenario("steady", n_tenants=n, intervals=intervals,
                             watch=True, backend=BACKEND)
    adv = replay_scenario("adversarial", n_tenants=n, intervals=intervals,
                          watch=True, backend=BACKEND)
    fail = replay_scenario("failover", n_tenants=n, intervals=intervals,
                           engines=engines, watch="record", backend=BACKEND)
    swap = replay_scenario("stack_swap", n_tenants=n, intervals=intervals,
                           engines=engines, watch=True, backend=BACKEND)
    _WATCHDOG_REPORTS.update(steady=steady, adversarial=adv,
                             failover=fail, stack_swap=swap)

    def tenant_alerts(rep, *, rule=None, exclude_tenant=None):
        out = []
        for a in rep.alerts or ():
            lbl = dict(a.labels)
            if rule is not None and a.rule != rule:
                continue
            if exclude_tenant is not None \
                    and lbl.get("tenant") == exclude_tenant:
                continue
            out.append(a)
        return out

    fairness_on_hog = sum(1 for a in tenant_alerts(adv,
                                                   rule="fairness_burn")
                          if dict(a.labels).get("tenant") == hog)
    nonhog = [a for a in (adv.alerts or ())
              if "tenant" in dict(a.labels)
              and dict(a.labels)["tenant"] != hog]
    dark = [a for a in (fail.alerts or ()) if a.rule == "engine_dark"]
    dark_resolved = sum(1 for a in dark if a.resolved_at is not None)
    # "quiet outside the quiesce window": nothing fleet-level pages
    # during the swaps, and no alert blames a well-behaved tenant
    offscript = [a for a in (swap.alerts or ())
                 if a.rule in ("engine_dark", "telemetry_stalled",
                               "conservation_drift", "jain_floor",
                               "parked_leak")
                 or dict(a.labels).get("tenant") not in (hog, None)]

    # per-tick watchdog cost, measured on the steady run's own registry
    # and store (the hot collect() path), against the watch-free wall.
    # Warm ticks first saturate the store's bounded retention so the
    # timed ticks see the steady-state window sizes, not a growing store
    wd = steady.watchdog
    last = wd.store.times()[-1]
    for i in range(wd.store.retention):
        wd.tick(last + 1.0 + i)
    reps = 100
    t1 = time.perf_counter()
    for i in range(reps):
        wd.tick(last + 1.0 + wd.store.retention + i)
    tick_s = (time.perf_counter() - t1) / reps
    ticks_per_run = intervals + 1
    overhead = tick_s * ticks_per_run / max(base_wall, 1e-9)

    rows = [("e2e_watchdog,steady_alerts", float(steady.alerts_fired)),
            ("e2e_watchdog,adversarial_alerts", float(adv.alerts_fired)),
            ("e2e_watchdog,adversarial_fairness_on_hog",
             float(fairness_on_hog)),
            ("e2e_watchdog,adversarial_nonhog_tenant_alerts",
             float(len(nonhog))),
            ("e2e_watchdog,failover_engine_dark_fired", float(len(dark))),
            ("e2e_watchdog,failover_engine_dark_resolved",
             float(dark_resolved)),
            ("e2e_watchdog,stack_swap_offscript_alerts",
             float(len(offscript))),
            ("e2e_watchdog,watchdog_tick_us", tick_s * 1e6),
            ("e2e_watchdog,step_overhead_frac", overhead)]
    ok = (steady.alerts_fired == 0 and fairness_on_hog >= 1
          and not nonhog and len(dark) >= 1 and dark_resolved >= 1
          and not offscript and overhead < 0.02)
    return {"rows": rows, "ok": ok,
            "claim": f"watchdog precision: steady fired 0, adversarial "
                     f"paged the hog only ({fairness_on_hog} fairness "
                     f"fire(s), {len(nonhog)} on others), failover "
                     f"engine-dark fired {len(dark)} / resolved "
                     f"{dark_resolved}, stack_swap off-script alerts "
                     f"{len(offscript)}; {tick_s * 1e6:.0f}us/tick = "
                     f"{overhead:.3%} of the watch-free wall (< 2%)"}


AUTOPILOT = (run_e2e_consolidation, run_e2e_hotspot, run_e2e_stack_swap,
             run_e2e_failover, run_e2e_watchdog)


def _parse_args(argv):
    opts = {"e2e": "--e2e" in argv, "smoke": "--smoke" in argv,
            "autopilot": "--autopilot" in argv, "engines": 1,
            "json": None, "trace": None, "swap-trace": None,
            "failover-trace": None, "alerts": None, "scrapes": None,
            "backend": "object"}
    for flag in ("--engines", "--json", "--trace", "--swap-trace",
                 "--failover-trace", "--alerts", "--scrapes", "--backend"):
        if flag in argv:
            i = argv.index(flag)
            if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
                raise SystemExit(f"{flag} needs a value")
            opts[flag.lstrip("-")] = argv[i + 1]
    if opts["engines"] != 1:
        try:
            opts["engines"] = int(opts["engines"])
        except ValueError:
            raise SystemExit(f"--engines needs an integer, "
                             f"got {opts['engines']!r}")
    if (opts["engines"] > 1 or opts["autopilot"] or opts["smoke"]) \
            and not opts["e2e"]:
        raise SystemExit("--engines/--autopilot/--smoke only apply to the "
                         "e2e suite: add --e2e")
    if opts["autopilot"] and opts["engines"] < 2:
        raise SystemExit("--autopilot needs a cluster: use --engines N "
                         "(N >= 2)")
    if opts["smoke"] and not opts["autopilot"]:
        raise SystemExit("--smoke runs only the autopilot claims: "
                         "add --autopilot")
    if (opts["trace"] or opts["swap-trace"] or opts["failover-trace"]) \
            and not opts["e2e"]:
        raise SystemExit("--trace/--swap-trace/--failover-trace record "
                         "the real datapath: add --e2e")
    if (opts["alerts"] or opts["scrapes"]) and not opts["autopilot"]:
        raise SystemExit("--alerts/--scrapes dump the watchdog claim's "
                         "artifacts: add --e2e --autopilot")
    if opts["backend"] not in ("object", "vectorized"):
        raise SystemExit(f"--backend must be 'object' or 'vectorized', "
                         f"got {opts['backend']!r}")
    if opts["backend"] != "object" and not opts["e2e"]:
        raise SystemExit("--backend selects the e2e control plane: "
                         "add --e2e")
    return opts


def main(argv=None) -> None:
    global BACKEND
    opts = _parse_args(sys.argv[1:] if argv is None else argv)
    BACKEND = opts["backend"]
    intervals = SMOKE_INTERVALS if opts["smoke"] else E2E_INTERVALS
    benches = []
    if not opts["smoke"]:
        benches = list(E2E if opts["e2e"] else ALL)
        if opts["engines"] > 1:
            def bench_multi(n=opts["engines"]):
                return run_e2e_multi_engine(n)
            bench_multi.__name__ = f"run_e2e_multi_engine_x{opts['engines']}"
            benches.append(bench_multi)
    if opts["autopilot"]:
        for fn in AUTOPILOT:
            def bench_ap(fn=fn, n=opts["engines"], iv=intervals):
                return fn(n, intervals=iv)
            bench_ap.__name__ = fn.__name__
            benches.append(bench_ap)

        def bench_tracer(iv=intervals):
            return run_tracer_overhead(intervals=iv)
        bench_tracer.__name__ = "run_tracer_overhead"
        benches.append(bench_tracer)
    print("name,value")
    failures, results = 0, []
    for bench in benches:
        out = bench()
        for name, value in out["rows"]:
            print(f"{name},{value:.4f}")
        status = "PASS" if out["ok"] else "FAIL"
        print(f"{bench.__name__},{status}: {out['claim']}", file=sys.stderr)
        failures += 0 if out["ok"] else 1
        results.append({"bench": bench.__name__, "ok": out["ok"],
                        "claim": out["claim"],
                        "metrics": {n: v for n, v in out["rows"]}})
    if opts["trace"]:
        # flight-recorder artifact: one full migration-scenario replay
        # (operator rebalance + maintenance drain/park/unpark) recorded as
        # Chrome trace-event JSON — tools/check_trace.py validates it,
        # chrome://tracing / Perfetto load it
        from repro.serve.replay import replay_scenario
        replay_scenario("migration", n_tenants=E2E_TENANTS,
                        intervals=max(intervals, SMOKE_INTERVALS),
                        trace_path=opts["trace"],
                        backend=BACKEND)
        print(f"wrote {opts['trace']} (migration scenario trace)",
              file=sys.stderr)
    if opts["swap-trace"]:
        # the hot-swap flight-recorder artifact: one stack_swap replay
        # (two live stack-module swaps mid-burst) — validated by
        # tools/check_trace.py --scenario stack_swap
        from repro.serve.replay import replay_scenario
        replay_scenario("stack_swap", n_tenants=E2E_TENANTS,
                        intervals=max(intervals, SMOKE_INTERVALS),
                        trace_path=opts["swap-trace"],
                        backend=BACKEND)
        print(f"wrote {opts['swap-trace']} (stack_swap scenario trace)",
              file=sys.stderr)
    if opts["failover-trace"]:
        # the failover flight-recorder artifact: one failover replay
        # (checkpoint cadence, kill, kill-and-restore recovery) —
        # validated by tools/check_trace.py --scenario failover
        from repro.serve.replay import replay_scenario
        replay_scenario("failover", n_tenants=E2E_TENANTS,
                        intervals=max(intervals, SMOKE_INTERVALS),
                        trace_path=opts["failover-trace"],
                        backend=BACKEND)
        print(f"wrote {opts['failover-trace']} (failover scenario trace)",
              file=sys.stderr)
    if opts["alerts"]:
        # the watchdog artifact: every gated scenario's alert outcome,
        # straight from the claim-(k) runs (no re-replay)
        doc = {}
        for scen, rep in sorted(_WATCHDOG_REPORTS.items()):
            doc[scen] = {
                "fired": rep.alerts_fired,
                "resolved": rep.alerts_resolved,
                "active_at_end": rep.alerts_active,
                "by_rule": rep.alerts_by_rule(),
                "alerts": [{"rule": a.rule, "severity": a.severity,
                            "labels": dict(a.labels),
                            "fired_at": a.fired_at,
                            "resolved_at": a.resolved_at,
                            "value": a.value}
                           for a in rep.alerts or ()]}
        pathlib.Path(opts["alerts"]).write_text(json.dumps(doc, indent=2)
                                                + "\n")
        print(f"wrote {opts['alerts']} (watchdog alert outcomes)",
              file=sys.stderr)
    if opts["scrapes"]:
        # the failover run records its scrapes (watch="record"), so the
        # incident is replayable offline: tools/nk_watch.py SCRAPES.txt
        fail_rep = _WATCHDOG_REPORTS.get("failover")
        if fail_rep is None or fail_rep.watchdog is None:
            print("--scrapes: no recorded failover run (did the watchdog "
                  "claim run?)", file=sys.stderr)
        else:
            fail_rep.watchdog.write_scrapes(opts["scrapes"])
            print(f"wrote {opts['scrapes']} (failover scrape sequence)",
                  file=sys.stderr)
    if opts["json"]:
        doc = {"ok": failures == 0,
               "suite": ("smoke" if opts["smoke"] else
                         "e2e" if opts["e2e"] else "fluid"),
               "backend": opts["backend"],
               "engines": opts["engines"],
               "intervals": intervals if opts["e2e"] else None,
               "results": results,
               "metrics": {n: v for r in results
                           for n, v in r["metrics"].items()}}
        pathlib.Path(opts["json"]).write_text(json.dumps(doc, indent=2)
                                              + "\n")
        print(f"wrote {opts['json']}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
