"""Paper Figs. 21/22 analog: fair bandwidth sharing on a shared bottleneck.

Three scenarios, all on the virtual-time harness (deterministic, sub-second):

  convergence   N tenants with unequal demands on one bottleneck, enforced
                by two CoreEngines (the distributed case). Claim (a):
                steady-state per-tenant throughput within 10% of the
                weighted max-min fair allocation.
  isolation     one tenant misbehaves (offers 10x the bottleneck). Claim
                (b): every other tenant's served rate degrades < 5% vs its
                isolated baseline (paper Fig. 22: per-VM isolation).
  backfill      a tenant goes idle mid-run. Claim (c): the freed share is
                re-absorbed by backlogged tenants (work conservation) and
                returned when the tenant comes back.

Run: PYTHONPATH=src python benchmarks/bench_fairness.py
Exit status 1 if any claim fails.

``--e2e`` replays the same claims through a *real* ServeEngine — jitted
prefill/decode, WFQ admission, RateController-enforced token buckets — and
measures every number from engine/scheduler ledgers (repro.serve.replay),
plus claim (d): delta-based push issues <= 25% of full-push set_rate calls
on the steady-state trace.

``--e2e --engines N`` additionally drives an N-engine fabric (one shared
controller, operator-controlled placement) through the adversarial window
with a live tenant migration mid-burst: claim (e) — Jain >= 0.95 and
isolation < 5% must hold across the migration, and the migrated tenant's
served-token ledger is conserved (no loss, no double-billing).
"""
from __future__ import annotations

import pathlib
import sys
from typing import Dict

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.control import SharedBottleneckSim, SimTenant  # noqa: E402

CAPACITY = 1_000_000.0      # bottleneck bytes/s
DT = 0.05
T_RUN = 12.0


def run_convergence() -> Dict:
    """3 unequal tenants + 2 engines: converge to weighted max-min fair."""
    tenants = [
        SimTenant(1, demand=0.15 * CAPACITY),            # satisfied
        SimTenant(2, demand=0.90 * CAPACITY),            # greedy
        SimTenant(3, demand=2.00 * CAPACITY),            # greedier
    ]
    sim = SharedBottleneckSim(tenants, CAPACITY, n_engines=2, dt=DT)
    res = sim.run(T_RUN)
    ref = sim.fair_reference()
    rows, worst = [], 0.0
    for t in sorted(ref):
        got = res.served_rate(t)
        err = abs(got - ref[t]) / ref[t]
        worst = max(worst, err)
        rows.append((f"convergence,tenant{t}_served_frac_of_fair",
                     got / ref[t]))
    rows.append(("convergence,max_rel_error", worst))
    rows.append(("convergence,utilization",
                 res.total_served_rate() / CAPACITY))
    return {"rows": rows, "ok": worst < 0.10,
            "claim": f"max deviation from max-min fair {worst:.1%} < 10%"}


def run_isolation() -> Dict:
    """A 10x-overloading tenant must not hurt in-budget tenants (>5%)."""
    normal = {1: 0.20 * CAPACITY, 2: 0.25 * CAPACITY, 3: 0.15 * CAPACITY}
    # isolated baselines: each normal tenant alone on the bottleneck
    base = {}
    for t, d in normal.items():
        sim = SharedBottleneckSim([SimTenant(t, d)], CAPACITY, dt=DT)
        base[t] = sim.run(T_RUN).served_rate(t)
    # shared run with the misbehaving tenant offering 10x capacity
    tenants = [SimTenant(t, d) for t, d in normal.items()]
    tenants.append(SimTenant(9, demand=10.0 * CAPACITY))
    sim = SharedBottleneckSim(tenants, CAPACITY, dt=DT)
    res = sim.run(T_RUN)
    rows, worst = [], 0.0
    for t in normal:
        degr = max(1.0 - res.served_rate(t) / base[t], 0.0)
        worst = max(worst, degr)
        rows.append((f"isolation,tenant{t}_degradation", degr))
    rows.append(("isolation,hog_served_frac_of_capacity",
                 res.served_rate(9) / CAPACITY))
    rows.append(("isolation,max_degradation", worst))
    return {"rows": rows, "ok": worst < 0.05,
            "claim": f"worst in-budget degradation {worst:.2%} < 5%"}


def run_backfill() -> Dict:
    """Idle tenant's share is re-absorbed, then returned when it's back."""
    def on_off(t):
        return 0.8 * CAPACITY if t < 4.0 or t >= 8.0 else 0.0

    tenants = [SimTenant(1, on_off), SimTenant(2, 2.0 * CAPACITY)]
    sim = SharedBottleneckSim(tenants, CAPACITY, dt=DT)
    sim.run(4.0)
    mid = sim.run(4.0)                      # tenant 1 idle
    back = sim.run(4.0)                     # tenant 1 returns
    absorbed = mid.served_rate(2, 0.4, 1.0) / CAPACITY
    returned = back.served_rate(1, 0.5, 1.0) / (0.5 * CAPACITY)
    rows = [("backfill,idle_phase_utilization_by_survivor", absorbed),
            ("backfill,returning_tenant_frac_of_fair", returned)]
    ok = absorbed > 0.90 and abs(returned - 1.0) < 0.15
    return {"rows": rows, "ok": ok,
            "claim": f"survivor absorbed {absorbed:.0%} of capacity; "
                     f"returning tenant at {returned:.0%} of fair share"}


ALL = (run_convergence, run_isolation, run_backfill)


# ---------------------------------------------------------------------------
# End-to-end replays (real ServeEngine; everything read from ledgers)
# ---------------------------------------------------------------------------

E2E_TENANTS = 4
E2E_INTERVALS = 18


def _e2e_report(trace, capacity, push_mode="full"):
    from repro.serve.replay import TraceReplayer, make_replay_engine
    eng = make_replay_engine(capacity=capacity, push_mode=push_mode)
    return TraceReplayer(eng, capacity=capacity).run(trace)


def run_e2e_convergence() -> Dict:
    """Claim (a) on the real datapath: Jain >= 0.95 and <10% max-min
    deviation, from ServeEngine ledgers."""
    from repro.serve.replay import scenario_spec
    trace, cap = scenario_spec("steady", n_tenants=E2E_TENANTS,
                               intervals=E2E_INTERVALS)
    rep = _e2e_report(trace, cap)
    jain, dev = rep.jain(), rep.max_min_deviation()
    rows = [("e2e_convergence,jain_index", jain),
            ("e2e_convergence,max_min_deviation", dev),
            ("e2e_convergence,utilization", rep.total_rate() / cap),
            ("e2e_convergence,decode_steps", float(rep.decode_steps))]
    for t, r in sorted(rep.per_tenant.items()):
        rows.append((f"e2e_convergence,tenant{t}_tokens_per_s",
                     r.achieved_rate))
    return {"rows": rows, "ok": jain >= 0.95 and dev < 0.10,
            "claim": f"ledger-measured Jain {jain:.3f} >= 0.95, "
                     f"max-min deviation {dev:.1%} < 10%"}


def run_e2e_isolation() -> Dict:
    """Claim (b) on the real datapath: 10x misbehaver, in-budget tenants
    degrade < 5% vs their hog-free baseline."""
    from repro.serve.replay import adversarial_baseline, scenario_spec
    n = E2E_TENANTS
    hog_trace, cap = scenario_spec("adversarial", n_tenants=n,
                                   intervals=E2E_INTERVALS)
    base_trace = adversarial_baseline(hog_trace)
    base = _e2e_report(base_trace, cap)
    shared = _e2e_report(hog_trace, cap)
    rows, worst = [], 0.0
    for t in range(n - 1):
        degr = max(1.0 - shared.per_tenant[t].achieved_rate
                   / base.per_tenant[t].achieved_rate, 0.0)
        worst = max(worst, degr)
        rows.append((f"e2e_isolation,tenant{t}_degradation", degr))
    hog = shared.per_tenant[n - 1]
    rows.append(("e2e_isolation,hog_served_frac_of_capacity",
                 hog.achieved_rate / cap))
    rows.append(("e2e_isolation,hog_mean_admit_wait_s",
                 hog.mean_admit_wait_s))
    rows.append(("e2e_isolation,max_degradation", worst))
    return {"rows": rows, "ok": worst < 0.05,
            "claim": f"worst in-budget degradation {worst:.2%} < 5% "
                     f"(real engine, hog held to "
                     f"{hog.achieved_rate / cap:.0%} of capacity)"}


def run_e2e_delta_push() -> Dict:
    """Claim (d): delta push issues <= 25% of full-push set_rate calls on
    the steady-state trace, with no enforcement quality loss."""
    from repro.serve.replay import scenario_spec
    trace, cap = scenario_spec("steady", n_tenants=E2E_TENANTS,
                               intervals=E2E_INTERVALS)
    full = _e2e_report(trace, cap, push_mode="full")
    delta = _e2e_report(trace, cap, push_mode="delta")
    frac = delta.set_rate_calls / max(full.set_rate_calls, 1)
    rows = [("e2e_delta_push,full_set_rate_calls",
             float(full.set_rate_calls)),
            ("e2e_delta_push,delta_set_rate_calls",
             float(delta.set_rate_calls)),
            ("e2e_delta_push,delta_frac_of_full", frac),
            ("e2e_delta_push,delta_jain", delta.jain())]
    ok = frac <= 0.25 and delta.jain() >= 0.95 \
        and delta.max_min_deviation() < 0.10
    return {"rows": rows, "ok": ok,
            "claim": f"delta push used {frac:.1%} of full-push set_rate "
                     f"calls ({delta.set_rate_calls} vs "
                     f"{full.set_rate_calls}), Jain {delta.jain():.3f}"}


def run_e2e_multi_engine(engines: int = 3) -> Dict:
    """Claims (a)+(b) on a multi-engine fabric, with a live migration.

    N ServeEngines share ONE RateController (one tokens/s bottleneck
    spanning the cluster). The adversarial 10x hog heats its engine;
    mid-window the operator rebalances — a live tenant migration whose
    served-token ledger must be conserved (no loss, no double-billing)
    while Jain stays >= 0.95 and in-budget degradation stays < 5% vs the
    hog-free baseline on the same cluster shape.
    """
    from repro.serve.replay import (
        TraceReplayer, adversarial_baseline, make_replay_cluster,
        scenario_spec,
    )
    n = E2E_TENANTS
    trace, cap = scenario_spec("migration", n_tenants=n,
                               intervals=E2E_INTERVALS)
    base_trace = adversarial_baseline(trace)

    def run(tr, events=None):
        cl = make_replay_cluster(capacity=cap, engines=engines)
        return TraceReplayer(cl, capacity=cap).run(tr, events=events), cl

    base, _ = run(base_trace)
    moved: Dict = {}

    def rebalance_event(cl, now):
        rec = cl.rebalance(now=now)
        if rec is not None:
            moved["rec"] = rec
            moved["ledger_at_move"] = cl.tenant_served_tokens(rec.tenant)

    shared, cl = run(trace, events=[(E2E_INTERVALS // 2, rebalance_event)])
    rows, worst = [], 0.0
    for t in range(n - 1):
        degr = max(1.0 - shared.per_tenant[t].achieved_rate
                   / base.per_tenant[t].achieved_rate, 0.0)
        worst = max(worst, degr)
        rows.append((f"e2e_multi,tenant{t}_degradation", degr))
    jain = shared.jain()
    rec = moved.get("rec")
    conserved = False
    if rec is not None:
        final = cl.tenant_served_tokens(rec.tenant)
        truth = cl.tenant_billed_ground_truth(rec.tenant)
        conserved = (final == truth
                     and final >= moved["ledger_at_move"])
        rows.append((f"e2e_multi,migrated_tenant", float(rec.tenant)))
        rows.append(("e2e_multi,migration_queued_moved",
                     float(rec.queued_moved)))
        rows.append(("e2e_multi,migrated_ledger_tokens", float(final)))
        rows.append(("e2e_multi,migrated_ground_truth_tokens",
                     float(truth)))
    rows += [("e2e_multi,engines", float(shared.engines)),
             ("e2e_multi,live_migrations", float(shared.migrations)),
             ("e2e_multi,jain_index", jain),
             ("e2e_multi,max_degradation", worst),
             ("e2e_multi,ledger_conserved", 1.0 if conserved else 0.0)]
    ok = (jain >= 0.95 and worst < 0.05 and shared.migrations >= 1
          and conserved)
    return {"rows": rows, "ok": ok,
            "claim": f"{engines}-engine fabric: Jain {jain:.3f} >= 0.95, "
                     f"worst degradation {worst:.2%} < 5%, "
                     f"{shared.migrations} live migration(s) with the "
                     f"served-token ledger conserved"}


E2E = (run_e2e_convergence, run_e2e_isolation, run_e2e_delta_push)


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    benches = list(E2E if "--e2e" in argv else ALL)
    if "--engines" in argv:
        if "--e2e" not in argv:
            raise SystemExit("--engines only applies to the e2e suite: "
                             "use --e2e --engines N")
        i = argv.index("--engines")
        if i + 1 >= len(argv):
            raise SystemExit("--engines needs a value, e.g. "
                             "--e2e --engines 3")
        try:
            n_eng = int(argv[i + 1])
        except ValueError:
            raise SystemExit(f"--engines needs an integer, "
                             f"got {argv[i + 1]!r}")
        if n_eng > 1:
            def bench_multi(n=n_eng):
                return run_e2e_multi_engine(n)
            bench_multi.__name__ = f"run_e2e_multi_engine_x{n_eng}"
            benches.append(bench_multi)
    print("name,value")
    failures = 0
    for bench in benches:
        out = bench()
        for name, value in out["rows"]:
            print(f"{name},{value:.4f}")
        status = "PASS" if out["ok"] else "FAIL"
        print(f"{bench.__name__},{status}: {out['claim']}", file=sys.stderr)
        failures += 0 if out["ok"] else 1
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
