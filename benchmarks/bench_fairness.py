"""Paper Figs. 21/22 analog: fair bandwidth sharing on a shared bottleneck.

Three scenarios, all on the virtual-time harness (deterministic, sub-second):

  convergence   N tenants with unequal demands on one bottleneck, enforced
                by two CoreEngines (the distributed case). Claim (a):
                steady-state per-tenant throughput within 10% of the
                weighted max-min fair allocation.
  isolation     one tenant misbehaves (offers 10x the bottleneck). Claim
                (b): every other tenant's served rate degrades < 5% vs its
                isolated baseline (paper Fig. 22: per-VM isolation).
  backfill      a tenant goes idle mid-run. Claim (c): the freed share is
                re-absorbed by backlogged tenants (work conservation) and
                returned when the tenant comes back.

Run: PYTHONPATH=src python benchmarks/bench_fairness.py
Exit status 1 if any claim fails.
"""
from __future__ import annotations

import pathlib
import sys
from typing import Dict

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.control import SharedBottleneckSim, SimTenant  # noqa: E402

CAPACITY = 1_000_000.0      # bottleneck bytes/s
DT = 0.05
T_RUN = 12.0


def run_convergence() -> Dict:
    """3 unequal tenants + 2 engines: converge to weighted max-min fair."""
    tenants = [
        SimTenant(1, demand=0.15 * CAPACITY),            # satisfied
        SimTenant(2, demand=0.90 * CAPACITY),            # greedy
        SimTenant(3, demand=2.00 * CAPACITY),            # greedier
    ]
    sim = SharedBottleneckSim(tenants, CAPACITY, n_engines=2, dt=DT)
    res = sim.run(T_RUN)
    ref = sim.fair_reference()
    rows, worst = [], 0.0
    for t in sorted(ref):
        got = res.served_rate(t)
        err = abs(got - ref[t]) / ref[t]
        worst = max(worst, err)
        rows.append((f"convergence,tenant{t}_served_frac_of_fair",
                     got / ref[t]))
    rows.append(("convergence,max_rel_error", worst))
    rows.append(("convergence,utilization",
                 res.total_served_rate() / CAPACITY))
    return {"rows": rows, "ok": worst < 0.10,
            "claim": f"max deviation from max-min fair {worst:.1%} < 10%"}


def run_isolation() -> Dict:
    """A 10x-overloading tenant must not hurt in-budget tenants (>5%)."""
    normal = {1: 0.20 * CAPACITY, 2: 0.25 * CAPACITY, 3: 0.15 * CAPACITY}
    # isolated baselines: each normal tenant alone on the bottleneck
    base = {}
    for t, d in normal.items():
        sim = SharedBottleneckSim([SimTenant(t, d)], CAPACITY, dt=DT)
        base[t] = sim.run(T_RUN).served_rate(t)
    # shared run with the misbehaving tenant offering 10x capacity
    tenants = [SimTenant(t, d) for t, d in normal.items()]
    tenants.append(SimTenant(9, demand=10.0 * CAPACITY))
    sim = SharedBottleneckSim(tenants, CAPACITY, dt=DT)
    res = sim.run(T_RUN)
    rows, worst = [], 0.0
    for t in normal:
        degr = max(1.0 - res.served_rate(t) / base[t], 0.0)
        worst = max(worst, degr)
        rows.append((f"isolation,tenant{t}_degradation", degr))
    rows.append(("isolation,hog_served_frac_of_capacity",
                 res.served_rate(9) / CAPACITY))
    rows.append(("isolation,max_degradation", worst))
    return {"rows": rows, "ok": worst < 0.05,
            "claim": f"worst in-budget degradation {worst:.2%} < 5%"}


def run_backfill() -> Dict:
    """Idle tenant's share is re-absorbed, then returned when it's back."""
    def on_off(t):
        return 0.8 * CAPACITY if t < 4.0 or t >= 8.0 else 0.0

    tenants = [SimTenant(1, on_off), SimTenant(2, 2.0 * CAPACITY)]
    sim = SharedBottleneckSim(tenants, CAPACITY, dt=DT)
    sim.run(4.0)
    mid = sim.run(4.0)                      # tenant 1 idle
    back = sim.run(4.0)                     # tenant 1 returns
    absorbed = mid.served_rate(2, 0.4, 1.0) / CAPACITY
    returned = back.served_rate(1, 0.5, 1.0) / (0.5 * CAPACITY)
    rows = [("backfill,idle_phase_utilization_by_survivor", absorbed),
            ("backfill,returning_tenant_frac_of_fair", returned)]
    ok = absorbed > 0.90 and abs(returned - 1.0) < 0.15
    return {"rows": rows, "ok": ok,
            "claim": f"survivor absorbed {absorbed:.0%} of capacity; "
                     f"returning tenant at {returned:.0%} of fair share"}


ALL = (run_convergence, run_isolation, run_backfill)


def main() -> None:
    print("name,value")
    failures = 0
    for bench in ALL:
        out = bench()
        for name, value in out["rows"]:
            print(f"{name},{value:.4f}")
        status = "PASS" if out["ok"] else "FAIL"
        print(f"{bench.__name__},{status}: {out['claim']}", file=sys.stderr)
        failures += 0 if out["ok"] else 1
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
