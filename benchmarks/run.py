# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import os
# 8 host devices so the scalability bench can sweep 1..8 (NOT 512 — that is
# dry-run-only; see src/repro/launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
import traceback


def main() -> None:
    from benchmarks.paper_benches import ALL
    print("name,us_per_call,derived")
    failures = 0
    for bench in ALL:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception:
            failures += 1
            print(f"{bench.__name__},-1,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
