"""Control-plane scale bench: the fused tick vs the object control plane.

NetKernel's pitch is fleet-level management by the operator; ROADMAP.md's
north star is 1M tenants. The control plane gets there only if one control
interval costs O(1) Python work, not O(tenants) object traffic — this
bench measures exactly that boundary:

  object      a real TenantScheduler + RateController (SchedulerTelemetry
              EWMA dicts, WaterFill/max_min_fair over dicts, TokenBucket
              set_rate per tenant) driven by a synthetic counter trace.
  vectorized  the same tick fused: VectorizedControlPlane — refill +
              EWMA + admission headroom + bisection water-fill + bucket
              retarget as ONE jitted step over flat arrays.

Per population size (1k / 10k / 100k tenants) it reports µs/tick for each
backend, the speedup, control-tick throughput in tenants/s, and the bytes
of control state touched per tick. A parity probe replays an identical
counter trace through both backends and asserts the allocations agree
within 1e-6 x capacity (``equal_allocations``).

Run: PYTHONPATH=src python benchmarks/bench_control_scale.py [--smoke]
     [--json OUT.json]

``--smoke`` is the CI bench-smoke variant (fewer timed ticks, object
backend capped at 10k — its 100k tick costs seconds by construction).
Thresholds live in benchmarks/bench_thresholds.json; control-plane
regressions fail CI exactly like fairness regressions do
(tools/check_bench.py). Exit status 1 if any claim fails.
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

CAPACITY = 1_000_000.0     # tokens/s across the population
DT = 1.0                   # control interval (virtual seconds)
BACKLOG_FRAC = 0.1         # fraction of tenants with queue depth


def _trace(n: int, seed: int = 0):
    """Synthetic per-tenant demand: weights, per-tick served increments
    (integers — cumulative counters), and the backlogged subset."""
    rng = np.random.default_rng(seed)
    weights = rng.choice([1.0, 2.0, 4.0], size=n).astype(np.float64)
    rates = rng.uniform(0.2, 2.0, size=n) * (CAPACITY / n)
    steps = np.maximum(np.round(rates * DT), 1.0)
    backlogged = rng.random(n) < BACKLOG_FRAC
    return weights, steps, backlogged


def _object_harness(n: int, weights, backlogged):
    """A real TenantScheduler + RateController wired the production way;
    served counters are advanced directly (the data plane is synthetic,
    the control plane is the genuine article)."""
    from repro.control.controller import RateController
    from repro.serve.scheduler import TenantScheduler

    sched = TenantScheduler(policy="wfq", charge_prompt=True)
    ctrl = RateController(CAPACITY,
                          weights={t: float(weights[t]) for t in range(n)},
                          alpha=0.5, push_mode="full")
    ctrl.attach_scheduler(sched)
    for t in range(n):
        sched.add_tenant(t, weight=float(weights[t]))
        if backlogged[t]:
            sched.queues[t].append(None)   # pending() counts length only
    return sched, ctrl


def _vec_harness(n: int, weights):
    from repro.control.vectorized import VectorizedControlPlane

    plane = VectorizedControlPlane(CAPACITY, alpha=0.5, headroom=1.25,
                                   scheduler_buckets=True)
    for t in range(n):
        plane.add_tenant(t, weight=float(weights[t]))
    return plane


def _time_object(n: int, ticks: int, warmup: int = 2):
    weights, steps, backlogged = _trace(n)
    sched, ctrl = _object_harness(n, weights, backlogged)
    served = np.zeros(n)
    now = 0.0
    for _ in range(warmup):
        served += steps
        for t in range(n):
            sched.served_tokens[t] = int(served[t])
        ctrl.tick(now)
        now += DT
    t0 = time.perf_counter()
    for _ in range(ticks):
        served += steps
        for t in range(n):
            sched.served_tokens[t] = int(served[t])
        ctrl.tick(now)
        now += DT
    wall = time.perf_counter() - t0
    # the counter bump is the synthetic data plane, not control cost;
    # subtract its measured price so the object backend isn't overbilled
    b0 = time.perf_counter()
    for _ in range(ticks):
        for t in range(n):
            sched.served_tokens[t] = int(served[t])
    bump = time.perf_counter() - b0
    return max(wall - bump, 1e-9) / ticks, ctrl


def _time_vec(n: int, ticks: int, warmup: int = 3):
    weights, steps, backlogged = _trace(n)
    plane = _vec_harness(n, weights)
    size = plane.index.size
    queue = np.where(backlogged, 1.0, 0.0)
    served = np.zeros(size)
    now = 0.0
    for _ in range(warmup):
        served = served + steps
        plane.tick(served, queue=queue, now=now)
        now += DT
    t0 = time.perf_counter()
    for _ in range(ticks):
        served = served + steps
        plane.tick(served, queue=queue, now=now)
        now += DT
    wall = time.perf_counter() - t0
    return wall / ticks, plane


def _parity(n: int, ticks: int = 5) -> float:
    """Replay one identical counter trace through both backends; 1.0 iff
    every tenant's final allocation agrees within 1e-6 x capacity."""
    weights, steps, backlogged = _trace(n)
    sched, ctrl = _object_harness(n, weights, backlogged)
    plane = _vec_harness(n, weights)
    queue = np.where(backlogged, 1.0, 0.0)
    served = np.zeros(n)
    now = 0.0
    for _ in range(ticks):
        served += steps
        for t in range(n):
            sched.served_tokens[t] = int(served[t])
        ctrl.tick(now)
        plane.tick(served, queue=queue, now=now)
        now += DT
    vec = plane.allocations()
    if set(ctrl.allocations) != set(vec):
        return 0.0
    worst = max(abs(ctrl.allocations[t] - vec[t]) for t in ctrl.allocations)
    return 1.0 if worst <= 1e-6 * CAPACITY else 0.0


def run_scale(n: int, *, label: str, vec_ticks: int, obj_ticks: int,
              parity: bool, smoke: bool):
    rows = []
    vec_s, plane = _time_vec(n, vec_ticks)
    tenants_per_s = n / vec_s
    state_bytes = plane.state_bytes()
    rows += [(f"{label},vec_us_per_tick", vec_s * 1e6),
             (f"{label},vec_tenants_per_s", tenants_per_s),
             (f"{label},state_bytes_per_tick", float(state_bytes)),
             (f"{label},state_bytes_per_tenant", state_bytes / n)]
    ok = tenants_per_s >= 1e6
    claim = (f"{n} tenants: fused tick {vec_s * 1e6:.0f}us "
             f"({tenants_per_s / 1e6:.1f}M tenants/s, "
             f"{state_bytes / n:.0f} B/tenant)")
    if obj_ticks:
        obj_s, _ = _time_object(n, obj_ticks)
        speedup = obj_s / vec_s
        rows += [(f"{label},object_us_per_tick", obj_s * 1e6),
                 (f"{label},speedup_x", speedup)]
        floor = 5.0 if n <= 1000 else 50.0
        ok = ok and speedup >= floor
        claim += (f"; object {obj_s * 1e6:.0f}us -> {speedup:.0f}x "
                  f"(>= {floor:.0f}x)")
    if parity:
        eq = _parity(min(n, 1000 if smoke else n))
        rows.append((f"{label},equal_allocations", eq))
        ok = ok and eq >= 1.0
        claim += f"; allocations match within 1e-6 x capacity: {eq == 1.0}"
    return {"rows": rows, "ok": ok, "claim": claim}


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    json_out = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            raise SystemExit("--json needs a value")
        json_out = argv[i + 1]
    scales = [
        # (n, label, vec_ticks, obj_ticks, parity)
        (1_000, "control_scale_1k", 20 if smoke else 50,
         5 if smoke else 10, True),
        (10_000, "control_scale_10k", 10 if smoke else 30,
         3 if smoke else 5, not smoke),
        (100_000, "control_scale_100k", 5 if smoke else 10, 0, False),
    ]
    print("name,value")
    failures, results = 0, []
    for n, label, vt, ot, par in scales:
        out = run_scale(n, label=label, vec_ticks=vt, obj_ticks=ot,
                        parity=par, smoke=smoke)
        for name, value in out["rows"]:
            print(f"{name},{value:.4f}")
        status = "PASS" if out["ok"] else "FAIL"
        print(f"{label},{status}: {out['claim']}", file=sys.stderr)
        failures += 0 if out["ok"] else 1
        results.append({"bench": label, "ok": out["ok"],
                        "claim": out["claim"],
                        "metrics": {nm: v for nm, v in out["rows"]}})
    if json_out:
        doc = {"ok": failures == 0,
               "suite": "control_scale_smoke" if smoke else "control_scale",
               "results": results,
               "metrics": {nm: v for r in results
                           for nm, v in r["metrics"].items()}}
        pathlib.Path(json_out).write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {json_out}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
